//! Dependency-free TCP line protocol for the co-clustering service.
//!
//! Framing: every request is one `\n`-terminated line — a verb followed
//! by space-separated `key=value` pairs. Every response starts with a
//! line beginning `OK` or `ERR <message>`; the `RESULT` verb's success
//! response additionally carries the two label vectors and a terminator:
//!
//! ```text
//! → SUBMIT matrix=planted k=3 seed=7 method=lamc-scc
//! ← OK id=1
//! → STATUS id=1
//! ← OK id=1 state=done cached=false
//! → RESULT id=1
//! ← OK id=1 k=3 rows=96 cols=80 cached=false
//! ← ROWS 0,1,2,0,…
//! ← COLS 1,0,2,1,…
//! ← END
//! → STATS
//! ← OK jobs_done=1 cache_hits=0 cache_misses=1 …
//! → SHUTDOWN
//! ← OK shutting-down
//! ```
//!
//! Values must not contain spaces or newlines (names are identifiers,
//! numbers are numbers); `LOAD` paths are the one field where this
//! bites, and the parser rejects offending requests rather than
//! truncating them. See `docs/SERVICE.md` for the full contract.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Context, Result};

use super::manager::JobSpec;
use crate::merge::Cocluster;

/// Wire protocol revision. Bumped on any framing change; `HELLO`
/// exchanges it so a shard router refuses to scatter work to a worker
/// speaking a different revision instead of desyncing mid-round.
pub const PROTO_VERSION: u64 = 1;

/// Hard ceiling on any binary request payload (ids + inline rows). A
/// router-to-worker block at this size would already be mis-planned, so
/// anything larger is treated as a framing error, not an allocation.
pub const MAX_BINARY_PAYLOAD_BYTES: usize = 1 << 30;

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Submit(JobSpec),
    Status { id: u64 },
    Result { id: u64 },
    /// Binary result framing (`RESULTB`): the success response is one
    /// `OK` header line followed by a length-prefixed binary block (see
    /// [`encode_labels_binary`]) instead of `ROWS`/`COLS` text lines —
    /// RCV1-scale label vectors ship in 4 bytes per label with no line
    /// length ceiling. Clients auto-negotiate: an old server answers
    /// `ERR unknown verb…` and the client falls back to `RESULT`.
    ResultBinary { id: u64 },
    Stats,
    /// Load a matrix into the registry: from a named dataset spec, a
    /// matrix file path, or a LAMC2/LAMC3 store (kept disk-resident). Exactly
    /// one of `dataset`/`path`/`store` must be given.
    Load {
        name: String,
        dataset: Option<String>,
        path: Option<String>,
        store: Option<String>,
        rows: Option<usize>,
        seed: u64,
    },
    Shutdown,
    /// Version handshake (`HELLO proto=1 version=0.6.0`). Workers
    /// reject a different `proto`; the shard router additionally
    /// requires an identical crate `version` before trusting
    /// byte-identity across nodes.
    ///
    /// `framing=binary` negotiates the unified binary response framing
    /// once for the whole connection: every subsequent `RESULT`,
    /// `EVENTS` and `SUBSCRIBE` reply ships its body as one
    /// length-prefixed, checksummed payload instead of text lines, with
    /// no per-verb negotiation. An old server rejects the unknown field
    /// (`check_known`), which the client treats exactly like the legacy
    /// `RESULTB`/`EVENTSB` "unknown verb" downgrade — it re-greets
    /// without `framing=` and falls back to per-verb negotiation. The
    /// per-verb binary verbs are kept one release behind as compat
    /// shims.
    Hello { proto: u64, version: String, framing: Option<String> },
    /// List the shard sets registered on this worker (one `SET` line
    /// per matrix, then `END`).
    Shards,
    /// Shard-router introspection (`OK workers=… live=…`). A plain
    /// worker answers a typed error.
    Route,
    /// Fetch a dense sub-block of a shard set. The request line is
    /// followed by a binary payload of `rows` + `cols` global ids
    /// (see [`encode_labels_binary`] — u32 LE each, u64 checksum);
    /// the response is an `OK rows=… cols=… bytes=…` header plus an
    /// [`encode_block`] payload.
    ///
    /// Optional trace context (`trace_id=` / `parent_span=`): when both
    /// are present the worker times the request as spans and appends a
    /// span block to the reply (header gains `span_bytes=`; see
    /// [`encode_spans_binary`]). Absent context leaves the reply
    /// byte-identical to the pre-span protocol.
    GatherBinary {
        name: String,
        rows: usize,
        cols: usize,
        trace_id: Option<u64>,
        parent_span: Option<u64>,
    },
    /// Execute one block job on the worker: the request line is
    /// followed by an [`encode_exec_payload`] binary payload (global
    /// row/col ids plus `inline` rows the worker does not own); the
    /// response is `OK clusters=… bytes=…` plus an [`encode_atoms`]
    /// payload of the resulting atom co-clusters. Carries the same
    /// optional trace context as [`Request::GatherBinary`].
    ExecBinary {
        name: String,
        method: String,
        k: usize,
        seed: u64,
        rows: usize,
        cols: usize,
        inline: usize,
        trace_id: Option<u64>,
        parent_span: Option<u64>,
    },
    /// Cursor-paged job-lifecycle events (`EVENTS id=3 after=17`): the
    /// success response is an `OK id=… count=… next=…` header, one
    /// `EVENT <record>` line per retained event with `seq > after`
    /// (`after` omitted ⇒ from the beginning), then `END`. `next=` is
    /// the cursor to pass on the next poll.
    Events { id: u64, after: Option<u64> },
    /// Binary event framing (`EVENTSB`): same cursor semantics, but the
    /// `EVENT` line bodies ship as one length-prefixed, checksummed
    /// payload (see [`encode_events_binary`]) after the `OK` header —
    /// mirrors the `RESULT`/`RESULTB` negotiation, so clients fall back
    /// to `EVENTS` against an old server.
    EventsBinary { id: u64, after: Option<u64> },
    /// Prometheus-style text exposition of the service counters: an
    /// `OK lines=…` header, `lines` body lines, then `END`.
    Metrics,
    /// Fetch a job's recorded span tree (`SPANS id=3`): an
    /// `OK id=… count=…` header, one `SPAN <record>` line per span in
    /// `(start_us, id)` order, then `END`. On a router the tree is the
    /// stitched cross-node tree.
    Spans { id: u64 },
    /// Seal `rows` new dense rows (`cols` wide) onto a served store
    /// (`APPEND name=m rows=2 cols=80`): the request line is followed
    /// by an [`encode_append_rows`] payload of row-major f32 values.
    /// The server appends them as a fresh band under a bumped append
    /// generation, invalidates cached results for the matrix, and (when
    /// a base run is retained) queues an incremental re-clustering job;
    /// the reply is `OK name=… rows=… generation=… job=…`.
    Append { name: String, rows: usize, cols: usize },
    /// Cursor-paged matrix feed (`SUBSCRIBE name=m after=17`):
    /// `MatrixAppended` / `LabelsUpdated` lifecycle events for a served
    /// matrix, with the same cursor semantics as [`Request::Events`].
    /// Ships only on the unified framing — the server answers a typed
    /// error unless the connection negotiated `HELLO framing=binary`.
    Subscribe { name: String, after: Option<u64> },
}

impl Request {
    /// Byte length of the binary payload that follows the request line,
    /// if this verb carries one. Checked arithmetic plus the
    /// [`MAX_BINARY_PAYLOAD_BYTES`] cap: a corrupt header must fail
    /// here, not inside a giant allocation.
    pub fn binary_payload_len(&self) -> Result<Option<usize>> {
        let len = match self {
            Request::GatherBinary { rows, cols, .. } => id_payload_len(*rows, *cols)?,
            Request::ExecBinary { rows, cols, inline, .. } => {
                exec_payload_len(*rows, *cols, *inline)?
            }
            Request::Append { rows, cols, .. } => append_payload_len(*rows, *cols)?,
            _ => return Ok(None),
        };
        Ok(Some(len))
    }
}

fn id_payload_len(rows: usize, cols: usize) -> Result<usize> {
    let len = rows
        .checked_add(cols)
        .and_then(|n| n.checked_mul(4))
        .and_then(|n| n.checked_add(8))
        .context("id payload length overflows")?;
    ensure!(len <= MAX_BINARY_PAYLOAD_BYTES, "id payload of {len} bytes exceeds the cap");
    Ok(len)
}

fn append_payload_len(rows: usize, cols: usize) -> Result<usize> {
    let len = rows
        .checked_mul(cols)
        .and_then(|n| n.checked_mul(4))
        .and_then(|n| n.checked_add(8))
        .context("append payload length overflows")?;
    ensure!(len <= MAX_BINARY_PAYLOAD_BYTES, "append payload of {len} bytes exceeds the cap");
    Ok(len)
}

fn exec_payload_len(rows: usize, cols: usize, inline: usize) -> Result<usize> {
    let per_inline = cols
        .checked_mul(4)
        .and_then(|n| n.checked_add(4))
        .context("inline row length overflows")?;
    let len = id_payload_len(rows, cols)?
        .checked_sub(8)
        .unwrap()
        .checked_add(inline.checked_mul(per_inline).context("inline payload overflows")?)
        .and_then(|n| n.checked_add(8))
        .context("exec payload length overflows")?;
    ensure!(len <= MAX_BINARY_PAYLOAD_BYTES, "exec payload of {len} bytes exceeds the cap");
    Ok(len)
}

/// Split `k=v` tokens into a map, rejecting malformed tokens.
pub fn kv_pairs(tokens: &[&str]) -> Result<BTreeMap<String, String>> {
    let mut map = BTreeMap::new();
    for t in tokens {
        let (k, v) = t
            .split_once('=')
            .with_context(|| format!("expected key=value, got '{t}'"))?;
        if k.is_empty() || v.is_empty() {
            bail!("empty key or value in '{t}'");
        }
        map.insert(k.to_string(), v.to_string());
    }
    Ok(map)
}

fn get_u64(map: &BTreeMap<String, String>, key: &str) -> Result<Option<u64>> {
    map.get(key)
        .map(|v| v.parse::<u64>().with_context(|| format!("{key}={v} is not an integer")))
        .transpose()
}

fn get_usize(map: &BTreeMap<String, String>, key: &str) -> Result<Option<usize>> {
    map.get(key)
        .map(|v| v.parse::<usize>().with_context(|| format!("{key}={v} is not an integer")))
        .transpose()
}

fn get_f64(map: &BTreeMap<String, String>, key: &str) -> Result<Option<f64>> {
    map.get(key)
        .map(|v| v.parse::<f64>().with_context(|| format!("{key}={v} is not a float")))
        .transpose()
}

fn require_id(map: &BTreeMap<String, String>) -> Result<u64> {
    get_u64(map, "id")?.context("missing id=")
}

fn check_known(map: &BTreeMap<String, String>, known: &[&str]) -> Result<()> {
    for k in map.keys() {
        if !known.contains(&k.as_str()) {
            bail!("unknown field '{k}' (known: {})", known.join(", "));
        }
    }
    Ok(())
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request> {
    let line = line.trim();
    let mut tokens = line.split_whitespace();
    let verb = tokens.next().context("empty request")?;
    let rest: Vec<&str> = tokens.collect();
    match verb {
        "SUBMIT" => {
            let map = kv_pairs(&rest)?;
            check_known(&map, &["matrix", "method", "k", "seed", "p-thresh", "tau", "workers"])?;
            let defaults = JobSpec::default();
            let spec = JobSpec {
                matrix: map.get("matrix").context("missing matrix=")?.clone(),
                method: map.get("method").cloned().unwrap_or(defaults.method),
                k: get_usize(&map, "k")?.unwrap_or(defaults.k),
                seed: get_u64(&map, "seed")?.unwrap_or(defaults.seed),
                p_thresh: get_f64(&map, "p-thresh")?.unwrap_or(defaults.p_thresh),
                tau: get_f64(&map, "tau")?.unwrap_or(defaults.tau),
                workers: get_usize(&map, "workers")?.unwrap_or(defaults.workers),
            };
            Ok(Request::Submit(spec))
        }
        "STATUS" => {
            let map = kv_pairs(&rest)?;
            check_known(&map, &["id"])?;
            Ok(Request::Status { id: require_id(&map)? })
        }
        "RESULT" => {
            let map = kv_pairs(&rest)?;
            check_known(&map, &["id"])?;
            Ok(Request::Result { id: require_id(&map)? })
        }
        "RESULTB" => {
            let map = kv_pairs(&rest)?;
            check_known(&map, &["id"])?;
            Ok(Request::ResultBinary { id: require_id(&map)? })
        }
        "STATS" => {
            if !rest.is_empty() {
                bail!("STATS takes no fields");
            }
            Ok(Request::Stats)
        }
        "LOAD" => {
            let map = kv_pairs(&rest)?;
            check_known(&map, &["name", "dataset", "path", "store", "rows", "seed"])?;
            let name = map.get("name").context("missing name=")?.clone();
            let dataset = map.get("dataset").cloned();
            let path = map.get("path").cloned();
            let store = map.get("store").cloned();
            let sources = [dataset.is_some(), path.is_some(), store.is_some()];
            if sources.iter().filter(|&&s| s).count() != 1 {
                bail!("LOAD needs exactly one of dataset=, path= or store=");
            }
            Ok(Request::Load {
                name,
                dataset,
                path,
                store,
                rows: get_usize(&map, "rows")?,
                seed: get_u64(&map, "seed")?.unwrap_or(42),
            })
        }
        "SHUTDOWN" => {
            if !rest.is_empty() {
                bail!("SHUTDOWN takes no fields");
            }
            Ok(Request::Shutdown)
        }
        "HELLO" => {
            let map = kv_pairs(&rest)?;
            check_known(&map, &["proto", "version", "framing"])?;
            let framing = map.get("framing").cloned();
            if let Some(f) = &framing {
                if f != "binary" && f != "text" {
                    bail!("unknown framing '{f}' (want binary|text)");
                }
            }
            Ok(Request::Hello {
                proto: get_u64(&map, "proto")?.context("missing proto=")?,
                version: map.get("version").context("missing version=")?.clone(),
                framing,
            })
        }
        "SHARDS" => {
            if !rest.is_empty() {
                bail!("SHARDS takes no fields");
            }
            Ok(Request::Shards)
        }
        "ROUTE" => {
            if !rest.is_empty() {
                bail!("ROUTE takes no fields");
            }
            Ok(Request::Route)
        }
        "GATHERB" => {
            let map = kv_pairs(&rest)?;
            check_known(&map, &["name", "rows", "cols", "trace_id", "parent_span"])?;
            let rows = get_usize(&map, "rows")?.context("missing rows=")?;
            let cols = get_usize(&map, "cols")?.context("missing cols=")?;
            if rows == 0 || cols == 0 {
                bail!("GATHERB needs rows>=1 and cols>=1");
            }
            Ok(Request::GatherBinary {
                name: map.get("name").context("missing name=")?.clone(),
                rows,
                cols,
                trace_id: get_u64(&map, "trace_id")?,
                parent_span: get_u64(&map, "parent_span")?,
            })
        }
        "EXECB" => {
            let map = kv_pairs(&rest)?;
            check_known(
                &map,
                &["name", "method", "k", "seed", "rows", "cols", "inline", "trace_id", "parent_span"],
            )?;
            let rows = get_usize(&map, "rows")?.context("missing rows=")?;
            let cols = get_usize(&map, "cols")?.context("missing cols=")?;
            let inline = get_usize(&map, "inline")?.unwrap_or(0);
            if rows == 0 || cols == 0 {
                bail!("EXECB needs rows>=1 and cols>=1");
            }
            if inline > rows {
                bail!("EXECB inline={inline} exceeds rows={rows}");
            }
            Ok(Request::ExecBinary {
                name: map.get("name").context("missing name=")?.clone(),
                method: map.get("method").context("missing method=")?.clone(),
                k: get_usize(&map, "k")?.context("missing k=")?,
                seed: get_u64(&map, "seed")?.context("missing seed=")?,
                rows,
                cols,
                inline,
                trace_id: get_u64(&map, "trace_id")?,
                parent_span: get_u64(&map, "parent_span")?,
            })
        }
        "EVENTS" => {
            let map = kv_pairs(&rest)?;
            check_known(&map, &["id", "after"])?;
            Ok(Request::Events { id: require_id(&map)?, after: get_u64(&map, "after")? })
        }
        "EVENTSB" => {
            let map = kv_pairs(&rest)?;
            check_known(&map, &["id", "after"])?;
            Ok(Request::EventsBinary { id: require_id(&map)?, after: get_u64(&map, "after")? })
        }
        "METRICS" => {
            if !rest.is_empty() {
                bail!("METRICS takes no fields");
            }
            Ok(Request::Metrics)
        }
        "SPANS" => {
            let map = kv_pairs(&rest)?;
            check_known(&map, &["id"])?;
            Ok(Request::Spans { id: require_id(&map)? })
        }
        "APPEND" => {
            let map = kv_pairs(&rest)?;
            check_known(&map, &["name", "rows", "cols"])?;
            let rows = get_usize(&map, "rows")?.context("missing rows=")?;
            let cols = get_usize(&map, "cols")?.context("missing cols=")?;
            if rows == 0 || cols == 0 {
                bail!("APPEND needs rows>=1 and cols>=1");
            }
            Ok(Request::Append {
                name: map.get("name").context("missing name=")?.clone(),
                rows,
                cols,
            })
        }
        "SUBSCRIBE" => {
            let map = kv_pairs(&rest)?;
            check_known(&map, &["name", "after"])?;
            Ok(Request::Subscribe {
                name: map.get("name").context("missing name=")?.clone(),
                after: get_u64(&map, "after")?,
            })
        }
        other => bail!(
            "unknown verb '{other}' (want SUBMIT|STATUS|RESULT|RESULTB|STATS|LOAD|HELLO|SHARDS|GATHERB|EXECB|ROUTE|EVENTS|EVENTSB|METRICS|SPANS|APPEND|SUBSCRIBE|SHUTDOWN)"
        ),
    }
}

/// Validate a string destined for a `key=value` field: whitespace would
/// split the token and a newline would split the *frame* (injecting a
/// second request — e.g. a smuggled `SHUTDOWN` — and desyncing every
/// later reply on the connection), so both are rejected at encode time.
pub fn ensure_token(field: &str, value: &str) -> Result<()> {
    if value.is_empty() {
        bail!("{field} must not be empty");
    }
    if value.chars().any(|c| c.is_whitespace() || c.is_control()) {
        bail!("{field} must not contain whitespace or control characters: {value:?}");
    }
    Ok(())
}

/// Encode a SUBMIT line for a spec (the client side of `parse_request`).
/// Errors if a field would break the line framing.
pub fn encode_submit(spec: &JobSpec) -> Result<String> {
    ensure_token("matrix", &spec.matrix)?;
    ensure_token("method", &spec.method)?;
    Ok(format!(
        "SUBMIT matrix={} method={} k={} seed={} p-thresh={} tau={} workers={}",
        spec.matrix, spec.method, spec.k, spec.seed, spec.p_thresh, spec.tau, spec.workers
    ))
}

/// Encode a label vector as the payload of a `ROWS`/`COLS` line.
pub fn encode_labels(labels: &[usize]) -> String {
    let mut out = String::with_capacity(labels.len() * 2);
    for (i, l) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&l.to_string());
    }
    out
}

/// Encode both label vectors as the binary `RESULTB` payload:
/// `u32` LE per label (row labels then column labels), then a trailing
/// `u64` LE checksum over the label bytes. The header line's `rows=` /
/// `cols=` counts are the length prefix, so there is no terminator and
/// no line-length ceiling — a 10M-row labelling is 40 MB of payload
/// instead of an unbounded comma-separated text line.
pub fn encode_labels_binary(row_labels: &[usize], col_labels: &[usize]) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity((row_labels.len() + col_labels.len()) * 4 + 8);
    for &l in row_labels.iter().chain(col_labels) {
        let l32 = u32::try_from(l).map_err(|_| anyhow::anyhow!("label {l} exceeds u32 range"))?;
        out.extend_from_slice(&l32.to_le_bytes());
    }
    let ck = crate::store::checksum_bytes(&out);
    out.extend_from_slice(&ck.to_le_bytes());
    Ok(out)
}

/// Decode a `RESULTB` payload (`rows`/`cols` from the header line).
pub fn decode_labels_binary(bytes: &[u8], rows: usize, cols: usize) -> Result<(Vec<usize>, Vec<usize>)> {
    let want = (rows + cols) * 4 + 8;
    if bytes.len() != want {
        bail!("binary result payload has {} bytes, want {want}", bytes.len());
    }
    let (labels, ck) = bytes.split_at(bytes.len() - 8);
    if crate::store::checksum_bytes(labels) != u64::from_le_bytes(ck.try_into().unwrap()) {
        bail!("binary result payload failed its checksum");
    }
    let decode = |range: std::ops::Range<usize>| -> Vec<usize> {
        labels[range.start * 4..range.end * 4]
            .chunks_exact(4)
            .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]) as usize)
            .collect()
    };
    Ok((decode(0..rows), decode(rows..rows + cols)))
}

/// Decode a `ROWS`/`COLS` payload back into labels.
pub fn decode_labels(s: &str) -> Result<Vec<usize>> {
    let s = s.trim();
    if s.is_empty() {
        return Ok(vec![]);
    }
    s.split(',')
        .map(|t| t.parse::<usize>().with_context(|| format!("bad label '{t}'")))
        .collect()
}

/// One shard set as advertised by a worker's `SHARDS` reply: parent
/// matrix identity plus the row bands this worker owns.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardSetInfo {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub nnz: u64,
    pub sparse: bool,
    /// Parent store content fingerprint — all workers sharding the
    /// same matrix must agree on it.
    pub fingerprint: u64,
    /// Owned bands as `(row_lo, row_hi)`, sorted by `row_lo`.
    pub bands: Vec<(usize, usize)>,
}

/// Encode one `SET` line of a `SHARDS` reply.
pub fn encode_shard_set(info: &ShardSetInfo) -> Result<String> {
    ensure_token("name", &info.name)?;
    ensure!(!info.bands.is_empty(), "shard set '{}' has no bands", info.name);
    let bands: Vec<String> =
        info.bands.iter().map(|&(lo, hi)| format!("{lo}-{hi}")).collect();
    Ok(format!(
        "SET name={} rows={} cols={} nnz={} sparse={} fingerprint={:016x} bands={}",
        info.name,
        info.rows,
        info.cols,
        info.nnz,
        u64::from(info.sparse),
        info.fingerprint,
        bands.join(";")
    ))
}

/// Parse one `SET` line (the worker-registration/discovery framing the
/// router trusts for topology building — malformed lines are typed
/// errors, never silently-skipped bands).
pub fn parse_shard_set(line: &str) -> Result<ShardSetInfo> {
    let mut tokens = line.trim().split_whitespace();
    ensure!(tokens.next() == Some("SET"), "expected a SET line, got '{}'", line.trim());
    let rest: Vec<&str> = tokens.collect();
    let map = kv_pairs(&rest)?;
    check_known(&map, &["name", "rows", "cols", "nnz", "sparse", "fingerprint", "bands"])?;
    let mut bands = Vec::new();
    for span in map.get("bands").context("missing bands=")?.split(';') {
        let (lo, hi) = span
            .split_once('-')
            .with_context(|| format!("malformed band '{span}' (want lo-hi)"))?;
        let lo: usize = lo.parse().with_context(|| format!("bad band start '{lo}'"))?;
        let hi: usize = hi.parse().with_context(|| format!("bad band end '{hi}'"))?;
        ensure!(lo < hi, "band {lo}-{hi} is empty");
        bands.push((lo, hi));
    }
    ensure!(!bands.is_empty(), "missing bands=");
    ensure!(
        bands.windows(2).all(|w| w[0].1 <= w[1].0),
        "bands are not sorted and disjoint"
    );
    let fingerprint = map.get("fingerprint").context("missing fingerprint=")?;
    Ok(ShardSetInfo {
        name: map.get("name").context("missing name=")?.clone(),
        rows: get_usize(&map, "rows")?.context("missing rows=")?,
        cols: get_usize(&map, "cols")?.context("missing cols=")?,
        nnz: get_u64(&map, "nnz")?.context("missing nnz=")?,
        sparse: get_u64(&map, "sparse")?.context("missing sparse=")? != 0,
        fingerprint: u64::from_str_radix(fingerprint, 16)
            .with_context(|| format!("fingerprint '{fingerprint}' is not hex"))?,
        bands,
    })
}

/// Encode a dense block as a `GATHERB` response payload: f32 LE values
/// in row-major order, then a trailing u64 LE checksum.
pub fn encode_block(values: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(values.len() * 4 + 8);
    for &v in values {
        out.extend_from_slice(&v.to_le_bytes());
    }
    let ck = crate::store::checksum_bytes(&out);
    out.extend_from_slice(&ck.to_le_bytes());
    out
}

/// Decode a `GATHERB` response payload (`values` = rows·cols from the
/// header line).
pub fn decode_block(bytes: &[u8], values: usize) -> Result<Vec<f32>> {
    let want = values * 4 + 8;
    ensure!(bytes.len() == want, "block payload has {} bytes, want {want}", bytes.len());
    let (data, ck) = bytes.split_at(bytes.len() - 8);
    ensure!(
        crate::store::checksum_bytes(data) == u64::from_le_bytes(ck.try_into().unwrap()),
        "block payload failed its checksum"
    );
    Ok(data
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

/// Encode dense rows as an `APPEND` request payload: row-major f32 LE
/// values (`rows × cols` of them), then a trailing u64 LE checksum —
/// the same shape as a `GATHERB` block reply, so the codec is shared.
pub fn encode_append_rows(values: &[f32]) -> Vec<u8> {
    encode_block(values)
}

/// Decode an `APPEND` payload against its header counts.
pub fn decode_append_rows(bytes: &[u8], rows: usize, cols: usize) -> Result<Vec<f32>> {
    decode_block(bytes, rows.checked_mul(cols).context("append shape overflows")?)
}

/// Encode an `EXECB` request payload: `rows` global row ids then `cols`
/// global col ids (u32 LE each), then `inline.len()` inline rows — each
/// a u32 LE *position into the job's row list* followed by `cols` f32
/// LE values — then a trailing u64 LE checksum.
pub fn encode_exec_payload(
    rows: &[usize],
    cols: &[usize],
    inline: &[(u32, Vec<f32>)],
) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(exec_payload_len(rows.len(), cols.len(), inline.len())?);
    for &id in rows.iter().chain(cols) {
        let id32 = u32::try_from(id).map_err(|_| anyhow::anyhow!("id {id} exceeds u32 range"))?;
        out.extend_from_slice(&id32.to_le_bytes());
    }
    for (pos, values) in inline {
        ensure!(
            (*pos as usize) < rows.len(),
            "inline position {pos} out of range (job has {} rows)",
            rows.len()
        );
        ensure!(
            values.len() == cols.len(),
            "inline row has {} values, job has {} columns",
            values.len(),
            cols.len()
        );
        out.extend_from_slice(&pos.to_le_bytes());
        for &v in values {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    let ck = crate::store::checksum_bytes(&out);
    out.extend_from_slice(&ck.to_le_bytes());
    Ok(out)
}

/// Decode an `EXECB` request payload against its header counts.
/// Returns `(row_ids, col_ids, inline_rows)`.
#[allow(clippy::type_complexity)]
pub fn decode_exec_payload(
    bytes: &[u8],
    rows: usize,
    cols: usize,
    inline: usize,
) -> Result<(Vec<usize>, Vec<usize>, Vec<(u32, Vec<f32>)>)> {
    let want = exec_payload_len(rows, cols, inline)?;
    ensure!(bytes.len() == want, "exec payload has {} bytes, want {want}", bytes.len());
    let (body, ck) = bytes.split_at(bytes.len() - 8);
    ensure!(
        crate::store::checksum_bytes(body) == u64::from_le_bytes(ck.try_into().unwrap()),
        "exec payload failed its checksum"
    );
    fn take_u32(body: &[u8], cur: &mut usize) -> u32 {
        let v = u32::from_le_bytes(body[*cur..*cur + 4].try_into().unwrap());
        *cur += 4;
        v
    }
    let mut cur = 0usize;
    let row_ids: Vec<usize> = (0..rows).map(|_| take_u32(body, &mut cur) as usize).collect();
    let col_ids: Vec<usize> = (0..cols).map(|_| take_u32(body, &mut cur) as usize).collect();
    let mut inline_rows = Vec::with_capacity(inline);
    let mut seen = vec![false; rows];
    for _ in 0..inline {
        let pos = take_u32(body, &mut cur);
        ensure!((pos as usize) < rows, "inline position {pos} out of range");
        ensure!(!seen[pos as usize], "duplicate inline position {pos}");
        seen[pos as usize] = true;
        let values: Vec<f32> = (0..cols)
            .map(|_| f32::from_bits(take_u32(body, &mut cur)))
            .collect();
        inline_rows.push((pos, values));
    }
    ensure!(cur == body.len(), "exec payload has {} trailing bytes", body.len() - cur);
    Ok((row_ids, col_ids, inline_rows))
}

/// Encode atom co-clusters as an `EXECB` response payload. Per cluster:
/// u32 LE row count, u32 LE col count, the sorted row ids then col ids
/// (u32 LE each), and the f64 LE objective; then a trailing u64 LE
/// checksum. Only fresh atoms ship (vote 1.0 everywhere, weight 1.0),
/// so [`decode_atoms`] rebuilds them through [`Cocluster::atom`] and
/// the wire hop is byte-lossless.
pub fn encode_atoms(atoms: &[Cocluster]) -> Vec<u8> {
    let mut out = Vec::new();
    for atom in atoms {
        out.extend_from_slice(&(atom.rows.len() as u32).to_le_bytes());
        out.extend_from_slice(&(atom.cols.len() as u32).to_le_bytes());
        for &id in atom.rows.iter().chain(&atom.cols) {
            out.extend_from_slice(&id.to_le_bytes());
        }
        out.extend_from_slice(&atom.quality.to_le_bytes());
    }
    let ck = crate::store::checksum_bytes(&out);
    out.extend_from_slice(&ck.to_le_bytes());
    out
}

/// Decode an `EXECB` response payload (`clusters` from the header).
pub fn decode_atoms(bytes: &[u8], clusters: usize) -> Result<Vec<Cocluster>> {
    ensure!(bytes.len() >= 8, "atom payload truncated");
    let (body, ck) = bytes.split_at(bytes.len() - 8);
    ensure!(
        crate::store::checksum_bytes(body) == u64::from_le_bytes(ck.try_into().unwrap()),
        "atom payload failed its checksum"
    );
    let mut cur = 0usize;
    let mut atoms = Vec::with_capacity(clusters);
    for _ in 0..clusters {
        ensure!(cur + 8 <= body.len(), "atom payload truncated");
        let n_rows = u32::from_le_bytes(body[cur..cur + 4].try_into().unwrap()) as usize;
        let n_cols = u32::from_le_bytes(body[cur + 4..cur + 8].try_into().unwrap()) as usize;
        cur += 8;
        let need = (n_rows + n_cols) * 4 + 8;
        ensure!(cur + need <= body.len(), "atom payload truncated");
        let mut ids = body[cur..cur + (n_rows + n_cols) * 4]
            .chunks_exact(4)
            .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]));
        let rows: Vec<u32> = ids.by_ref().take(n_rows).collect();
        let cols: Vec<u32> = ids.collect();
        cur += (n_rows + n_cols) * 4;
        let quality = f64::from_le_bytes(body[cur..cur + 8].try_into().unwrap());
        cur += 8;
        atoms.push(Cocluster::atom(rows, cols, quality));
    }
    ensure!(cur == body.len(), "atom payload has {} trailing bytes", body.len() - cur);
    Ok(atoms)
}

/// Encode `EVENT` line bodies as an `EVENTSB` response payload: the
/// UTF-8 wire lines joined by `\n` (no trailing newline), then a
/// trailing u64 LE checksum. The header's `bytes=` field is the text
/// length, so the full payload is `bytes + 8`.
pub fn encode_events_binary(records: &[crate::trace::EventRecord]) -> Vec<u8> {
    let text = records.iter().map(|r| r.to_wire()).collect::<Vec<_>>().join("\n");
    let mut out = text.into_bytes();
    let ck = crate::store::checksum_bytes(&out);
    out.extend_from_slice(&ck.to_le_bytes());
    out
}

/// Decode an `EVENTSB` payload back into `EVENT` line bodies (`count`
/// from the header line).
pub fn decode_events_binary(bytes: &[u8], count: usize) -> Result<Vec<String>> {
    ensure!(bytes.len() >= 8, "event payload truncated ({} bytes)", bytes.len());
    let (body, ck) = bytes.split_at(bytes.len() - 8);
    ensure!(
        crate::store::checksum_bytes(body) == u64::from_le_bytes(ck.try_into().unwrap()),
        "event payload failed its checksum"
    );
    let text = std::str::from_utf8(body).context("event payload is not UTF-8")?;
    let lines: Vec<String> =
        if text.is_empty() { vec![] } else { text.lines().map(str::to_string).collect() };
    ensure!(lines.len() == count, "event payload has {} lines, header says {count}", lines.len());
    Ok(lines)
}

/// Encode a span sheet as the trailing span block of a traced
/// `EXECB`/`GATHERB` reply (and the payload shape behind `span_bytes=`):
/// the `SPAN` line bodies joined by `\n` (no trailing newline), then a
/// trailing u64 LE checksum. The header's `span_bytes=` field is the
/// text length, so the full block is `span_bytes + 8`.
pub fn encode_spans_binary(spans: &[crate::trace::SpanRecord]) -> Vec<u8> {
    let text = spans.iter().map(|s| s.to_wire()).collect::<Vec<_>>().join("\n");
    let mut out = text.into_bytes();
    let ck = crate::store::checksum_bytes(&out);
    out.extend_from_slice(&ck.to_le_bytes());
    out
}

/// Decode a span block (`span_bytes + 8` bytes) back into records.
pub fn decode_spans_binary(bytes: &[u8]) -> Result<Vec<crate::trace::SpanRecord>> {
    ensure!(bytes.len() >= 8, "span block truncated ({} bytes)", bytes.len());
    let (body, ck) = bytes.split_at(bytes.len() - 8);
    ensure!(
        crate::store::checksum_bytes(body) == u64::from_le_bytes(ck.try_into().unwrap()),
        "span block failed its checksum"
    );
    let text = std::str::from_utf8(body).context("span block is not UTF-8")?;
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(crate::trace::SpanRecord::from_wire)
        .collect()
}

/// Builder for the `METRICS` reply body: Prometheus-style text
/// exposition (`# HELP`/`# TYPE` declarations + `name{labels} value`
/// samples). The reply header's `lines=` count frames the body and an
/// `END` line terminates it — see `docs/OBSERVABILITY.md` for the exact
/// shape.
#[derive(Debug, Default)]
pub struct MetricsText {
    body: String,
    lines: usize,
}

impl MetricsText {
    pub fn new() -> MetricsText {
        MetricsText::default()
    }

    /// Declare a metric: `# HELP <name> <help>` + `# TYPE <name>
    /// <gauge|counter|histogram>`. Every family gets both lines —
    /// `scripts/metrics_lint.py` enforces the pairing.
    pub fn declare(&mut self, name: &str, mtype: &str, help: &str) -> &mut Self {
        self.body.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {mtype}\n"));
        self.lines += 2;
        self
    }

    /// Append one sample; `series` carries any labels verbatim (e.g.
    /// `lamc_jobs{state="queued"}`).
    pub fn sample(&mut self, series: &str, value: impl std::fmt::Display) -> &mut Self {
        self.body.push_str(&format!("{series} {value}\n"));
        self.lines += 1;
        self
    }

    /// Declaration plus single unlabelled sample, counter-typed.
    pub fn counter(&mut self, name: &str, value: impl std::fmt::Display, help: &str) -> &mut Self {
        self.declare(name, "counter", help).sample(name, value)
    }

    /// Declaration plus single unlabelled sample, gauge-typed.
    pub fn gauge(&mut self, name: &str, value: impl std::fmt::Display, help: &str) -> &mut Self {
        self.declare(name, "gauge", help).sample(name, value)
    }

    /// Append one labelled series of a histogram family: cumulative
    /// `_bucket` samples in `le` order terminated by `le="+Inf"`
    /// (whose count equals `_count`), then `_sum` (seconds) and
    /// `_count`. `labels` is the extra label list without braces
    /// (`phase="gather"`, or `""` for none). Declare the family once
    /// with `declare(name, "histogram", …)` before the first series.
    pub fn histogram_series(
        &mut self,
        name: &str,
        labels: &str,
        snap: &crate::coordinator::stats::HistogramSnapshot,
    ) -> &mut Self {
        use crate::coordinator::stats::HIST_BOUNDS;
        let sep = if labels.is_empty() { "" } else { "," };
        for (i, cum) in snap.cumulative().iter().enumerate() {
            let le = match HIST_BOUNDS.get(i) {
                Some(b) => b.to_string(),
                None => "+Inf".to_string(),
            };
            self.sample(&format!("{name}_bucket{{{labels}{sep}le=\"{le}\"}}"), cum);
        }
        let braces = |suffix: &str| {
            if labels.is_empty() {
                format!("{name}_{suffix}")
            } else {
                format!("{name}_{suffix}{{{labels}}}")
            }
        };
        self.sample(&braces("sum"), format!("{:.9}", snap.sum_ns as f64 / 1e9));
        self.sample(&braces("count"), snap.count)
    }

    /// `(body, line_count)`; the body carries one trailing `\n` per
    /// line, so it can be written verbatim before the `END` line.
    pub fn finish(self) -> (String, usize) {
        (self.body, self.lines)
    }
}

/// First line of an error response.
pub fn err_line(msg: &str) -> String {
    // Newlines would break framing; flatten them.
    format!("ERR {}", msg.replace('\n', "; "))
}

/// Split a response line into (ok, rest). `Err` if it is an ERR line.
pub fn check_ok(line: &str) -> Result<&str> {
    let line = line.trim_end();
    if let Some(rest) = line.strip_prefix("OK") {
        return Ok(rest.trim_start());
    }
    if let Some(msg) = line.strip_prefix("ERR") {
        bail!("server error: {}", msg.trim_start());
    }
    bail!("malformed response line: '{line}'")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_round_trip() {
        let spec = JobSpec {
            matrix: "planted".into(),
            method: "lamc-pnmtf".into(),
            k: 5,
            seed: 99,
            p_thresh: 0.9,
            tau: 0.4,
            workers: 3,
        };
        let line = encode_submit(&spec).unwrap();
        match parse_request(&line).unwrap() {
            Request::Submit(parsed) => assert_eq!(parsed, spec),
            other => panic!("wrong parse: {other:?}"),
        }
    }

    #[test]
    fn submit_defaults_apply() {
        match parse_request("SUBMIT matrix=m").unwrap() {
            Request::Submit(s) => {
                assert_eq!(s.method, "lamc-scc");
                assert_eq!(s.k, 4);
                assert_eq!(s.seed, 42);
            }
            other => panic!("wrong parse: {other:?}"),
        }
    }

    #[test]
    fn simple_verbs() {
        assert_eq!(parse_request("STATUS id=7").unwrap(), Request::Status { id: 7 });
        assert_eq!(parse_request("RESULT id=1").unwrap(), Request::Result { id: 1 });
        assert_eq!(parse_request("RESULTB id=2").unwrap(), Request::ResultBinary { id: 2 });
        assert_eq!(parse_request("STATS").unwrap(), Request::Stats);
        assert_eq!(parse_request("SHUTDOWN\n").unwrap(), Request::Shutdown);
    }

    #[test]
    fn load_requires_exactly_one_source() {
        assert!(parse_request("LOAD name=x dataset=amazon1000").is_ok());
        assert!(parse_request("LOAD name=x path=/tmp/m.lamc rows=100").is_ok());
        assert!(parse_request("LOAD name=x store=/tmp/m.lamc2").is_ok());
        assert!(parse_request("LOAD name=x").is_err());
        assert!(parse_request("LOAD name=x dataset=a path=b").is_err());
        assert!(parse_request("LOAD name=x dataset=a store=b").is_err());
        assert!(parse_request("LOAD name=x path=a store=b").is_err());
    }

    #[test]
    fn malformed_requests_error() {
        assert!(parse_request("").is_err());
        assert!(parse_request("FROBNICATE").is_err());
        assert!(parse_request("SUBMIT").is_err(), "matrix is required");
        assert!(parse_request("SUBMIT matrix=m k=abc").is_err());
        assert!(parse_request("SUBMIT matrix=m bogus=1").is_err(), "unknown field");
        assert!(parse_request("STATUS").is_err(), "id required");
        assert!(parse_request("STATS extra=1").is_err());
    }

    #[test]
    fn encode_rejects_frame_breaking_fields() {
        let inject = JobSpec { matrix: "x\nSHUTDOWN".into(), ..JobSpec::default() };
        assert!(encode_submit(&inject).is_err(), "newline would smuggle a second request");
        let spaced = JobSpec { matrix: "a b".into(), ..JobSpec::default() };
        assert!(encode_submit(&spaced).is_err(), "space would split the token");
        assert!(ensure_token("name", "ok-name_1.2").is_ok());
        assert!(ensure_token("name", "").is_err());
    }

    #[test]
    fn label_codec_round_trip() {
        let labels = vec![0usize, 3, 1, 1, 2, 0];
        assert_eq!(decode_labels(&encode_labels(&labels)).unwrap(), labels);
        assert_eq!(decode_labels("").unwrap(), Vec::<usize>::new());
        assert!(decode_labels("1,x,2").is_err());
    }

    #[test]
    fn binary_label_codec_round_trip() {
        let rows = vec![0usize, 3, 1, 1, 2, 0, 7];
        let cols = vec![2usize, 2, 0];
        let bytes = encode_labels_binary(&rows, &cols).unwrap();
        assert_eq!(bytes.len(), (rows.len() + cols.len()) * 4 + 8);
        let (r2, c2) = decode_labels_binary(&bytes, rows.len(), cols.len()).unwrap();
        assert_eq!(r2, rows);
        assert_eq!(c2, cols);
        // Empty labellings frame fine too.
        let empty = encode_labels_binary(&[], &[]).unwrap();
        assert_eq!(decode_labels_binary(&empty, 0, 0).unwrap(), (vec![], vec![]));
    }

    #[test]
    fn binary_label_codec_rejects_damage() {
        let bytes = encode_labels_binary(&[1, 2, 3], &[0]).unwrap();
        // Length mismatch against the header counts.
        assert!(decode_labels_binary(&bytes, 3, 2).is_err());
        // Bit flip fails the checksum.
        let mut bad = bytes.clone();
        bad[0] ^= 0x01;
        assert!(decode_labels_binary(&bad, 3, 1).is_err());
    }

    #[test]
    fn response_line_helpers() {
        assert_eq!(check_ok("OK id=3\n").unwrap(), "id=3");
        assert_eq!(check_ok("OK").unwrap(), "");
        assert!(check_ok("ERR boom").is_err());
        assert!(check_ok("??").is_err());
        assert!(!err_line("a\nb").contains('\n'));
    }

    #[test]
    fn shard_verbs_parse() {
        assert_eq!(
            parse_request("HELLO proto=1 version=0.1.0").unwrap(),
            Request::Hello { proto: 1, version: "0.1.0".into(), framing: None }
        );
        assert_eq!(
            parse_request("HELLO proto=1 version=0.1.0 framing=binary").unwrap(),
            Request::Hello { proto: 1, version: "0.1.0".into(), framing: Some("binary".into()) }
        );
        assert!(parse_request("HELLO proto=1 version=0.1.0 framing=gopher").is_err());
        assert_eq!(parse_request("SHARDS").unwrap(), Request::Shards);
        assert_eq!(parse_request("ROUTE").unwrap(), Request::Route);
        assert_eq!(
            parse_request("GATHERB name=m rows=3 cols=2").unwrap(),
            Request::GatherBinary {
                name: "m".into(),
                rows: 3,
                cols: 2,
                trace_id: None,
                parent_span: None,
            }
        );
        assert_eq!(
            parse_request("EXECB name=m method=scc k=3 seed=9 rows=4 cols=2 inline=1").unwrap(),
            Request::ExecBinary {
                name: "m".into(),
                method: "scc".into(),
                k: 3,
                seed: 9,
                rows: 4,
                cols: 2,
                inline: 1,
                trace_id: None,
                parent_span: None,
            }
        );
    }

    #[test]
    fn trace_context_rides_the_block_verbs() {
        // The wire round-trip of (trace_id, parent_span) through EXECB:
        // both optional, parsed when present, None when absent.
        match parse_request("EXECB name=m method=scc k=3 seed=9 rows=4 cols=2 inline=0 trace_id=12 parent_span=34")
            .unwrap()
        {
            Request::ExecBinary { trace_id, parent_span, rows, .. } => {
                assert_eq!(trace_id, Some(12));
                assert_eq!(parent_span, Some(34));
                assert_eq!(rows, 4, "payload counts are unaffected by trace context");
            }
            other => panic!("wrong parse: {other:?}"),
        }
        match parse_request("GATHERB name=m rows=3 cols=2 trace_id=5 parent_span=6").unwrap() {
            Request::GatherBinary { trace_id, parent_span, .. } => {
                assert_eq!(trace_id, Some(5));
                assert_eq!(parent_span, Some(6));
            }
            other => panic!("wrong parse: {other:?}"),
        }
        assert!(parse_request("EXECB name=m method=scc k=3 seed=9 rows=4 cols=2 trace_id=x").is_err());
    }

    #[test]
    fn malformed_shard_verbs_error() {
        // ROUTE/SHARDS are field-free; trailing junk is a typed error.
        assert!(parse_request("ROUTE workers=2").is_err());
        assert!(parse_request("SHARDS all=1").is_err());
        assert!(parse_request("HELLO").is_err(), "proto required");
        assert!(parse_request("HELLO proto=x version=1").is_err());
        assert!(parse_request("GATHERB name=m rows=0 cols=2").is_err(), "empty block");
        assert!(parse_request("GATHERB rows=1 cols=1").is_err(), "name required");
        assert!(parse_request("EXECB name=m method=scc k=3 seed=9 rows=2 cols=2 inline=5").is_err());
        assert!(parse_request("EXECB name=m method=scc seed=9 rows=2 cols=2").is_err(), "k required");
        assert!(parse_request("EXECB name=m method=scc k=3 seed=9 rows=2 cols=2 bogus=1").is_err());
    }

    #[test]
    fn binary_payload_lengths_are_checked() {
        let gather = parse_request("GATHERB name=m rows=3 cols=2").unwrap();
        assert_eq!(gather.binary_payload_len().unwrap(), Some(5 * 4 + 8));
        let exec = parse_request("EXECB name=m method=scc k=2 seed=1 rows=4 cols=3 inline=2").unwrap();
        assert_eq!(exec.binary_payload_len().unwrap(), Some(7 * 4 + 2 * (4 + 12) + 8));
        assert_eq!(parse_request("STATS").unwrap().binary_payload_len().unwrap(), None);
        // A corrupt header asking for an absurd payload fails the cap
        // instead of reaching an allocation.
        let huge = Request::GatherBinary {
            name: "m".into(),
            rows: usize::MAX / 8,
            cols: 1,
            trace_id: None,
            parent_span: None,
        };
        assert!(huge.binary_payload_len().is_err());
    }

    #[test]
    fn shard_set_line_round_trip() {
        let info = ShardSetInfo {
            name: "cc".into(),
            rows: 300,
            cols: 1000,
            nnz: 37_000,
            sparse: true,
            fingerprint: 0x00a1_b2c3_d4e5_f607,
            bands: vec![(0, 128), (256, 300)],
        };
        let line = encode_shard_set(&info).unwrap();
        assert_eq!(parse_shard_set(&line).unwrap(), info);
    }

    #[test]
    fn malformed_shard_set_lines_error() {
        assert!(parse_shard_set("OK nope").is_err(), "not a SET line");
        assert!(parse_shard_set("SET name=m rows=4 cols=4 nnz=16 sparse=0 fingerprint=ff").is_err(), "bands required");
        let base = "SET name=m rows=4 cols=4 nnz=16 sparse=0 fingerprint=ff";
        assert!(parse_shard_set(&format!("{base} bands=5")).is_err(), "band needs lo-hi");
        assert!(parse_shard_set(&format!("{base} bands=3-3")).is_err(), "empty band");
        assert!(parse_shard_set(&format!("{base} bands=2-4;0-2")).is_err(), "unsorted bands");
        assert!(parse_shard_set(&format!("{base} bands=0-3;2-4")).is_err(), "overlapping bands");
        assert!(
            parse_shard_set("SET name=m rows=4 cols=4 nnz=16 sparse=0 fingerprint=zz bands=0-4").is_err(),
            "fingerprint must be hex"
        );
    }

    #[test]
    fn block_codec_round_trip_and_damage() {
        let values = vec![1.5f32, -2.25, 0.0, 3.125, f32::MIN_POSITIVE, -0.0];
        let bytes = encode_block(&values);
        let back = decode_block(&bytes, values.len()).unwrap();
        // Byte-exact, not just approximately equal: -0.0 keeps its sign bit.
        assert_eq!(
            back.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            values.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert!(decode_block(&bytes, values.len() + 1).is_err(), "length mismatch");
        let mut bad = bytes.clone();
        bad[2] ^= 0x40;
        assert!(decode_block(&bad, values.len()).is_err(), "checksum catches bit flips");
    }

    #[test]
    fn exec_payload_round_trip_and_damage() {
        let rows = vec![10usize, 40, 41, 99];
        let cols = vec![3usize, 7];
        let inline = vec![(1u32, vec![0.5f32, -1.5]), (3u32, vec![2.0, 4.0])];
        let bytes = encode_exec_payload(&rows, &cols, &inline).unwrap();
        let (r2, c2, i2) = decode_exec_payload(&bytes, 4, 2, 2).unwrap();
        assert_eq!(r2, rows);
        assert_eq!(c2, cols);
        assert_eq!(i2, inline);

        // Duplicate inline position is rejected at decode.
        let dup = vec![(1u32, vec![0.5f32, -1.5]), (1u32, vec![2.0, 4.0])];
        let bytes = encode_exec_payload(&rows, &cols, &dup).unwrap();
        assert!(decode_exec_payload(&bytes, 4, 2, 2).is_err());

        // Out-of-range position is rejected at encode.
        assert!(encode_exec_payload(&rows, &cols, &[(9, vec![0.0, 0.0])]).is_err());
        // Width mismatch too.
        assert!(encode_exec_payload(&rows, &cols, &[(0, vec![0.0])]).is_err());
    }

    #[test]
    fn observability_verbs_parse() {
        assert_eq!(parse_request("EVENTS id=4").unwrap(), Request::Events { id: 4, after: None });
        assert_eq!(
            parse_request("EVENTS id=4 after=17").unwrap(),
            Request::Events { id: 4, after: Some(17) }
        );
        assert_eq!(
            parse_request("EVENTSB id=9 after=0").unwrap(),
            Request::EventsBinary { id: 9, after: Some(0) }
        );
        assert_eq!(parse_request("METRICS").unwrap(), Request::Metrics);
        // None of the three carries a request payload.
        for line in ["EVENTS id=1", "EVENTSB id=1", "METRICS"] {
            assert_eq!(parse_request(line).unwrap().binary_payload_len().unwrap(), None);
        }
    }

    #[test]
    fn malformed_observability_verbs_error() {
        assert!(parse_request("EVENTS").is_err(), "id required");
        assert!(parse_request("EVENTS id=1 cursor=2").is_err(), "unknown field");
        assert!(parse_request("EVENTS id=1 after=x").is_err(), "cursor must be an integer");
        assert!(parse_request("EVENTSB after=1").is_err(), "id required");
        assert!(parse_request("METRICS all=1").is_err(), "field-free verb");
    }

    #[test]
    fn events_binary_codec_round_trip_and_damage() {
        use crate::trace::{Event, EventRecord};
        let records = vec![
            EventRecord { seq: 0, t_ms: 1, event: Event::JobQueued },
            EventRecord { seq: 1, t_ms: 2, event: Event::RoundStarted { round: 0, jobs: 4 } },
            EventRecord {
                seq: 2,
                t_ms: 9,
                event: Event::JobFailed { error: "worker lost".into() },
            },
        ];
        let bytes = encode_events_binary(&records);
        let lines = decode_events_binary(&bytes, records.len()).unwrap();
        assert_eq!(lines.len(), 3);
        for (line, rec) in lines.iter().zip(&records) {
            assert_eq!(line, &rec.to_wire());
        }

        assert!(decode_events_binary(&bytes, 2).is_err(), "count mismatch");
        let mut bad = bytes.clone();
        bad[3] ^= 0x20;
        assert!(decode_events_binary(&bad, 3).is_err(), "checksum catches bit flips");
        assert!(decode_events_binary(&[], 0).is_err(), "missing checksum is typed");

        let empty = encode_events_binary(&[]);
        assert_eq!(empty.len(), 8, "empty page is just the checksum");
        assert_eq!(decode_events_binary(&empty, 0).unwrap(), Vec::<String>::new());
    }

    #[test]
    fn metrics_text_builder_frames_lines() {
        let mut m = MetricsText::new();
        m.counter("lamc_cache_hits_total", 3u64, "Result-cache hits.")
            .declare("lamc_jobs", "gauge", "Jobs by state.")
            .sample("lamc_jobs{state=\"queued\"}", 1u64)
            .sample("lamc_jobs{state=\"running\"}", 0u64)
            .gauge("lamc_gather_seconds", 0.25f64, "Gather time.");
        let (body, lines) = m.finish();
        assert_eq!(lines, 10, "3 counter + 4 jobs + 3 gauge lines");
        assert_eq!(body.lines().count(), lines);
        assert!(body.contains("# HELP lamc_cache_hits_total Result-cache hits.\n"));
        assert!(body.contains("# TYPE lamc_cache_hits_total counter\n"));
        assert!(body.contains("lamc_jobs{state=\"queued\"} 1\n"));
        assert!(body.contains("lamc_gather_seconds 0.25\n"));
        assert!(body.ends_with('\n'));
    }

    #[test]
    fn metrics_histograms_render_cumulative_le_series() {
        use crate::coordinator::stats::{Histogram, HIST_BUCKETS};
        let h = Histogram::default();
        h.observe_ns(500_000); // le 0.001
        h.observe_ns(40_000_000_000); // +Inf
        let snap = h.snapshot();
        let mut m = MetricsText::new();
        m.declare("lamc_round_seconds", "histogram", "Round phase latency.")
            .histogram_series("lamc_round_seconds", "phase=\"gather\"", &snap)
            .histogram_series("lamc_round_seconds", "phase=\"exec\"", &Default::default());
        let (body, lines) = m.finish();
        assert_eq!(lines, 2 + 2 * (HIST_BUCKETS + 2));
        assert!(body.contains("# TYPE lamc_round_seconds histogram\n"));
        assert!(body.contains("lamc_round_seconds_bucket{phase=\"gather\",le=\"0.001\"} 1\n"));
        assert!(body.contains("lamc_round_seconds_bucket{phase=\"gather\",le=\"+Inf\"} 2\n"));
        assert!(body.contains("lamc_round_seconds_sum{phase=\"gather\"} 40.000500000\n"));
        assert!(body.contains("lamc_round_seconds_count{phase=\"gather\"} 2\n"));
        assert!(body.contains("lamc_round_seconds_bucket{phase=\"exec\",le=\"+Inf\"} 0\n"));
        // Cumulative within a series: every gather bucket after 0.001
        // also reports the first observation.
        assert!(body.contains("lamc_round_seconds_bucket{phase=\"gather\",le=\"0.5\"} 1\n"));
    }

    #[test]
    fn unlabelled_histogram_series_render_bare_sum_and_count() {
        let mut m = MetricsText::new();
        m.declare("lamc_queue_wait_seconds", "histogram", "Queue wait.")
            .histogram_series("lamc_queue_wait_seconds", "", &Default::default());
        let (body, _) = m.finish();
        assert!(body.contains("lamc_queue_wait_seconds_bucket{le=\"+Inf\"} 0\n"));
        assert!(body.contains("lamc_queue_wait_seconds_sum 0.000000000\n"));
        assert!(body.contains("lamc_queue_wait_seconds_count 0\n"));
    }

    #[test]
    fn streaming_verbs_parse() {
        assert_eq!(
            parse_request("APPEND name=m rows=2 cols=80").unwrap(),
            Request::Append { name: "m".into(), rows: 2, cols: 80 }
        );
        assert_eq!(
            parse_request("SUBSCRIBE name=m").unwrap(),
            Request::Subscribe { name: "m".into(), after: None }
        );
        assert_eq!(
            parse_request("SUBSCRIBE name=m after=9").unwrap(),
            Request::Subscribe { name: "m".into(), after: Some(9) }
        );
        assert!(parse_request("APPEND rows=2 cols=80").is_err(), "name required");
        assert!(parse_request("APPEND name=m rows=0 cols=80").is_err(), "empty append");
        assert!(parse_request("APPEND name=m rows=2").is_err(), "cols required");
        assert!(parse_request("SUBSCRIBE after=1").is_err(), "name required");
        assert!(parse_request("SUBSCRIBE name=m id=1").is_err(), "unknown field");
    }

    #[test]
    fn append_payload_length_and_codec() {
        let req = parse_request("APPEND name=m rows=2 cols=3").unwrap();
        assert_eq!(req.binary_payload_len().unwrap(), Some(2 * 3 * 4 + 8));
        assert_eq!(
            parse_request("SUBSCRIBE name=m").unwrap().binary_payload_len().unwrap(),
            None
        );
        let values = vec![1.0f32, 2.0, 3.0, -4.0, 0.5, 6.25];
        let bytes = encode_append_rows(&values);
        assert_eq!(bytes.len(), 2 * 3 * 4 + 8);
        assert_eq!(decode_append_rows(&bytes, 2, 3).unwrap(), values);
        assert!(decode_append_rows(&bytes, 2, 2).is_err(), "shape mismatch");
        let mut bad = bytes.clone();
        bad[5] ^= 0x10;
        assert!(decode_append_rows(&bad, 2, 3).is_err(), "checksum catches bit flips");
        // A corrupt header asking for an absurd payload fails the cap.
        let huge = Request::Append { name: "m".into(), rows: usize::MAX / 8, cols: 2 };
        assert!(huge.binary_payload_len().is_err());
    }

    #[test]
    fn spans_verb_parses() {
        assert_eq!(parse_request("SPANS id=6").unwrap(), Request::Spans { id: 6 });
        assert!(parse_request("SPANS").is_err(), "id required");
        assert!(parse_request("SPANS id=1 after=2").is_err(), "no cursor on SPANS");
        assert_eq!(parse_request("SPANS id=1").unwrap().binary_payload_len().unwrap(), None);
    }

    #[test]
    fn span_block_codec_round_trip_and_damage() {
        use crate::trace::SpanRecord;
        let spans = vec![
            SpanRecord { id: 1, parent: 0, name: "gather".into(), worker: 0, start_us: 3, dur_us: 40 },
            SpanRecord { id: 2, parent: 1, name: "exec".into(), worker: 0, start_us: 43, dur_us: 900 },
        ];
        let bytes = encode_spans_binary(&spans);
        assert_eq!(decode_spans_binary(&bytes).unwrap(), spans);
        let mut bad = bytes.clone();
        bad[1] ^= 0x08;
        assert!(decode_spans_binary(&bad).is_err(), "checksum catches bit flips");
        assert!(decode_spans_binary(&[]).is_err(), "missing checksum is typed");
        let empty = encode_spans_binary(&[]);
        assert_eq!(empty.len(), 8, "empty sheet is just the checksum");
        assert!(decode_spans_binary(&empty).unwrap().is_empty());
    }

    #[test]
    fn atom_codec_round_trip_and_damage() {
        let atoms = vec![
            Cocluster::atom(vec![4, 1, 9], vec![0, 2], -3.5),
            Cocluster::atom(vec![7], vec![5, 6, 8], 0.25),
        ];
        let bytes = encode_atoms(&atoms);
        let back = decode_atoms(&bytes, atoms.len()).unwrap();
        assert_eq!(back, atoms, "atoms survive the hop byte-identically");

        assert!(decode_atoms(&bytes, atoms.len() + 1).is_err(), "count mismatch");
        assert!(decode_atoms(&bytes, atoms.len() - 1).is_err(), "trailing bytes rejected");
        let mut bad = bytes.clone();
        bad[4] ^= 0x01;
        assert!(decode_atoms(&bad, atoms.len()).is_err(), "checksum catches bit flips");
        assert!(decode_atoms(&[], 0).is_err(), "missing checksum is typed");
        let empty = encode_atoms(&[]);
        assert_eq!(decode_atoms(&empty, 0).unwrap(), vec![]);
    }
}
