//! Long-lived co-clustering service: persistent worker pool, job queue,
//! result cache, and a dependency-free TCP line protocol.
//!
//! The paper's leader/worker design (§IV-C) originally lived inside a
//! one-shot batch call — every `pipeline::Lamc::run` re-created its
//! worker threads and nothing survived between requests. This module
//! turns that pipeline into a service for repeated and concurrent
//! co-clustering requests over the same (or different) matrices:
//!
//! * [`WorkerPool`] — long-lived block-execution threads fed by a job
//!   channel; `coordinator::run_rounds` executes on the shared global
//!   pool, so thread startup is amortized across every request (batch
//!   CLI runs included).
//! * [`ServiceManager`] — owns a named-matrix registry of
//!   [`MatrixRef`](crate::store::MatrixRef) handles (in-memory matrices
//!   with memoized `Matrix::fingerprint` hashes, or disk-resident LAMC2
//!   stores whose fingerprint is read from the header in O(1)), a
//!   bounded job queue for backpressure, runner threads, per-job
//!   `Queued → Running → Done/Failed` state, and a TTL sweep that keeps
//!   the job map bounded on long-lived servers.
//! * [`ResultCache`] — byte-bounded LRU keyed by (matrix fingerprint,
//!   canonical config hash): an identical re-submission is answered
//!   without running the pipeline, with hit/miss counters surfaced
//!   through `coordinator::Stats`. With a `--store-root` configured,
//!   entries spill to disk and survive a restart.
//! * [`protocol`] / [`ServiceServer`] / [`ServiceClient`] — a
//!   `SUBMIT`/`STATUS`/`RESULT`/`RESULTB`/`STATS`/`LOAD`/`SHUTDOWN`
//!   protocol over `std::net`, thread-per-connection, with a blocking
//!   client. Control verbs are text lines; `RESULTB` answers with a
//!   length-prefixed binary label block (no line-length ceiling) and
//!   clients fall back to text `RESULT` against older servers; `LOAD`
//!   accepts `dataset=`, `path=` or `store=` sources.
//!
//! * Observability (`docs/OBSERVABILITY.md`) — every job owns a
//!   [`trace::Journal`](crate::trace::Journal) of typed lifecycle
//!   events, paged over the wire with the cursor verbs
//!   `EVENTS`/`EVENTSB` (`lamc watch`), and the `METRICS` verb renders
//!   the `STATS` counters as Prometheus-style text exposition
//!   (`lamc metrics`).
//!
//! * [`shard`] — a shard router fronting multiple worker nodes: each
//!   worker serves row bands of a sharded store (`lamc serve --shards`,
//!   advertised over `HELLO`/`SHARDS`), and a [`ShardRouter`] scatters
//!   block jobs by band ownership (`GATHERB`/`EXECB`), reduces partial
//!   co-cluster sets through one global consensus merge, and retries
//!   jobs lost to dead workers — with labels byte-identical to a
//!   single-node run.
//!
//! Wire format and operational knobs are documented in
//! `docs/SERVICE.md`; the `lamc serve` / `lamc submit` / `lamc status`
//! / `lamc shard` / `lamc route` CLI commands are thin wrappers over
//! these types.

pub mod cache;
pub mod client;
pub mod manager;
pub mod pool;
pub mod protocol;
pub mod server;
pub mod shard;

pub use cache::{CacheKey, JobOutput, ResultCache};
pub use client::{AppendReply, ResultReply, ServiceClient, StatusReply};
pub use manager::{
    AppendOutcome, BoundedQueue, JobRecord, JobSpec, JobState, QueueRejection, ServiceConfig,
    ServiceManager, ShardBand, ShardSet,
};
pub use pool::WorkerPool;
pub use server::ServiceServer;
pub use shard::{RoutedRun, ShardError, ShardRouter, ShardRouterConfig, ShardServer};
