//! Benchmark harness (criterion is not available offline — see DESIGN.md).
//!
//! Provides warmup + repeated timing with median/mean/min reporting and
//! a tiny table printer used by the Table II/III reproduction benches.

use std::time::Instant;

/// Timing summary over repeated runs.
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    pub median_s: f64,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub runs: usize,
}

impl Timing {
    pub fn format(&self) -> String {
        if self.median_s >= 1.0 {
            format!("{:.3} s (min {:.3}, n={})", self.median_s, self.min_s, self.runs)
        } else {
            format!("{:.3} ms (min {:.3}, n={})", self.median_s * 1e3, self.min_s * 1e3, self.runs)
        }
    }
}

/// Time `f` with `warmup` unmeasured runs then `runs` measured runs.
pub fn bench<F: FnMut()>(warmup: usize, runs: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(runs.max(1));
    for _ in 0..runs.max(1) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    // NaN-safe total order: a NaN sample (e.g. from a zero-duration
    // division in a caller) must not panic the whole bench run.
    samples.sort_by(f64::total_cmp);
    let n = samples.len();
    Timing {
        median_s: samples[n / 2],
        mean_s: samples.iter().sum::<f64>() / n as f64,
        min_s: samples[0],
        max_s: samples[n - 1],
        runs: n,
    }
}

/// Time one run of `f`, returning (result, seconds).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

/// The `--json PATH` argument of a bench invocation, if present. Every
/// other argument is ignored — `cargo bench` appends its own flags
/// (e.g. `--bench`) to harness-less bench binaries.
pub fn json_arg_path() -> Option<std::path::PathBuf> {
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--json" {
            return args.next().map(Into::into);
        }
    }
    None
}

/// Fixed-width table printer for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "table row arity");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_ordered_stats() {
        let t = bench(1, 5, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert!(t.min_s <= t.median_s && t.median_s <= t.max_s);
        assert_eq!(t.runs, 5);
    }

    #[test]
    fn time_once_returns_result() {
        let (v, s) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "2".into()]);
        let out = t.render();
        assert!(out.contains("long-name"));
        assert_eq!(out.lines().count(), 4);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_checks_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
