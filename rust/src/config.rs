//! Minimal configuration system (TOML-subset, dependency-free).
//!
//! Supports the subset the launcher needs: `key = value` pairs, `[section]`
//! headers, strings, integers, floats, booleans, and `#` comments.
//! Values are stored flat as `section.key` strings with typed getters.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Parsed configuration: flat `section.key → raw value` map.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("line {}: malformed section header: {raw}", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let Some(eq) = line.find('=') else {
                bail!("line {}: expected key = value: {raw}", lineno + 1);
            };
            let key = line[..eq].trim();
            let mut value = line[eq + 1..].trim().to_string();
            if value.len() >= 2 && ((value.starts_with('"') && value.ends_with('"')) || (value.starts_with('\'') && value.ends_with('\''))) {
                value = value[1..value.len() - 1].to_string();
            }
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            values.insert(full, value);
        }
        Ok(Self { values })
    }

    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read config {path:?}"))?;
        Self::parse(&text)
    }

    /// Override / insert a raw value (CLI `--set section.key=value`).
    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        self.values
            .get(key)
            .map(|v| v.parse::<usize>().with_context(|| format!("{key} = {v} is not an integer")))
            .transpose()
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        self.values
            .get(key)
            .map(|v| v.parse::<f64>().with_context(|| format!("{key} = {v} is not a float")))
            .transpose()
    }

    pub fn get_bool(&self, key: &str) -> Result<Option<bool>> {
        self.values
            .get(key)
            .map(|v| match v.as_str() {
                "true" | "yes" | "1" => Ok(true),
                "false" | "no" | "0" => Ok(false),
                other => bail!("{key} = {other} is not a boolean"),
            })
            .transpose()
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    let mut quote = ' ';
    for (i, ch) in line.char_indices() {
        match ch {
            '"' | '\'' if !in_str => {
                in_str = true;
                quote = ch;
            }
            c if in_str && c == quote => in_str = false,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# top-level
seed = 42
name = "lamc run"   # trailing comment

[partition]
p_thresh = 0.95
max_samplings = 16
use_lsh = true
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get_usize("seed").unwrap(), Some(42));
        assert_eq!(c.get_str("name"), Some("lamc run"));
        assert_eq!(c.get_f64("partition.p_thresh").unwrap(), Some(0.95));
        assert_eq!(c.get_usize("partition.max_samplings").unwrap(), Some(16));
        assert_eq!(c.get_bool("partition.use_lsh").unwrap(), Some(true));
    }

    #[test]
    fn missing_keys_are_none() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get_str("nope"), None);
        assert_eq!(c.get_usize("also.nope").unwrap(), None);
    }

    #[test]
    fn type_errors_are_reported() {
        let c = Config::parse("x = hello").unwrap();
        assert!(c.get_usize("x").is_err());
        assert!(c.get_bool("x").is_err());
    }

    #[test]
    fn set_overrides() {
        let mut c = Config::parse(SAMPLE).unwrap();
        c.set("partition.p_thresh", "0.5");
        assert_eq!(c.get_f64("partition.p_thresh").unwrap(), Some(0.5));
    }

    #[test]
    fn malformed_lines_error() {
        assert!(Config::parse("[unclosed").is_err());
        assert!(Config::parse("no equals here").is_err());
        assert!(Config::parse("= novalue").is_err());
    }

    #[test]
    fn hash_inside_quotes_kept() {
        let c = Config::parse("tag = \"a#b\"").unwrap();
        assert_eq!(c.get_str("tag"), Some("a#b"));
    }
}
