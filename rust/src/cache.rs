//! The one byte-bounded LRU behind every cache in the stack.
//!
//! Three subsystems need the same policy — keep the hottest entries
//! resident while the total payload stays under a byte budget, evicting
//! the least-recently-used first:
//!
//! * the service's result cache ([`crate::service`], finished labellings
//!   keyed by `(matrix fingerprint, config hash)`),
//! * the store reader's decoded-chunk cache ([`crate::store`], row bands
//!   and tiles re-read across co-clustering rounds),
//! * the result cache's disk-spill pruner (spilled `.lamcres` files,
//!   oldest-first by spill recency).
//!
//! Each used to carry its own copy of the eviction loop; [`ByteLru`] is
//! the single shared implementation. It is deliberately *not*
//! thread-safe — every caller already serializes access behind its own
//! `Mutex`, and hit/miss accounting stays with the caller (only the
//! caller knows what a miss costs); the LRU itself tracks what nobody
//! else can observe: resident bytes, the high-water mark, and evictions.
//!
//! Recency is a monotonic tick per entry plus a `BTreeMap` from tick to
//! key, so lookup stays O(1) expected and eviction is O(log n) — flat
//! enough for every caller, from a result cache holding tens of
//! labellings to a spill-directory replay over a hundred thousand
//! files, without `unsafe` or hand-rolled linked lists.

use std::collections::{BTreeMap, HashMap};
use std::hash::Hash;

struct Slot<V> {
    value: V,
    bytes: usize,
    last_used: u64,
}

/// What [`ByteLru::insert`] displaced.
///
/// `evicted` holds entries pushed out to make room (oldest first);
/// `replaced` is the previous value under the same key (not an
/// eviction — the key stayed resident); `rejected` is the new value
/// itself when it exceeds the whole budget and was never admitted.
#[derive(Debug)]
pub struct Insertion<K, V> {
    pub evicted: Vec<(K, V)>,
    pub replaced: Option<V>,
    pub rejected: Option<V>,
}

impl<K, V> Insertion<K, V> {
    fn empty() -> Self {
        Insertion { evicted: Vec::new(), replaced: None, rejected: None }
    }
}

/// A byte-bounded least-recently-used map.
///
/// Entries carry an explicit byte weight (the value's resident size as
/// the caller measures it). `insert` keeps the total weight at or under
/// `capacity`, evicting stale entries — never the key just inserted —
/// and returning everything it displaced so the caller can count, drop,
/// or delete (the disk pruner turns evictions into `remove_file`s).
///
/// A value larger than the entire capacity is rejected rather than
/// admitted-then-evicted; capacity 0 therefore disables the cache.
pub struct ByteLru<K, V> {
    map: HashMap<K, Slot<V>>,
    /// Recency index: `last_used` tick → key. Ticks are unique (one
    /// counter, bumped per touch), so the smallest tick is the LRU
    /// entry and eviction is a `pop_first`.
    order: BTreeMap<u64, K>,
    capacity: usize,
    bytes: usize,
    peak_bytes: usize,
    tick: u64,
    evictions: u64,
}

impl<K: Eq + Hash + Clone, V> ByteLru<K, V> {
    pub fn new(capacity: usize) -> Self {
        Self {
            map: HashMap::new(),
            order: BTreeMap::new(),
            capacity,
            bytes: 0,
            peak_bytes: 0,
            tick: 0,
            evictions: 0,
        }
    }

    /// Byte budget this cache holds its entries under.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Current resident payload bytes.
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// High-water mark of [`bytes`](Self::bytes) over the cache's
    /// lifetime — the proof a bounded-memory pass actually stayed
    /// bounded (the repack memory-guard test asserts on this).
    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    /// Entries evicted to keep the budget (rejections and same-key
    /// replacements are not evictions).
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn contains(&self, key: &K) -> bool {
        self.map.contains_key(key)
    }

    /// Look up and refresh recency.
    pub fn get(&mut self, key: &K) -> Option<&V> {
        self.tick += 1;
        let tick = self.tick;
        match self.map.get_mut(key) {
            Some(slot) => {
                self.order.remove(&slot.last_used);
                self.order.insert(tick, key.clone());
                slot.last_used = tick;
                Some(&slot.value)
            }
            None => None,
        }
    }

    /// Look up without touching recency.
    pub fn peek(&self, key: &K) -> Option<&V> {
        self.map.get(key).map(|s| &s.value)
    }

    /// Remove an entry, returning its value. Not an eviction.
    pub fn remove(&mut self, key: &K) -> Option<V> {
        self.map.remove(key).map(|slot| {
            self.order.remove(&slot.last_used);
            self.bytes -= slot.bytes;
            slot.value
        })
    }

    /// Forcibly evict the least-recently-used entry, returning it (and
    /// counting it as an eviction). The store prefetcher uses this to
    /// push out stale never-consumed chunks when a plan has moved on —
    /// the one caller that needs to reclaim room *without* inserting.
    pub fn pop_lru(&mut self) -> Option<(K, V)> {
        let (_, key) = self.order.pop_first()?;
        let slot = self.map.remove(&key).expect("order index out of sync");
        self.bytes -= slot.bytes;
        self.evictions += 1;
        Some((key, slot.value))
    }

    /// Insert `value` under `key` with an explicit byte weight, evicting
    /// least-recently-used entries until the budget holds. See
    /// [`Insertion`] for what comes back out.
    pub fn insert(&mut self, key: K, value: V, bytes: usize) -> Insertion<K, V> {
        let mut out = Insertion::empty();
        if bytes > self.capacity {
            out.rejected = Some(value);
            return out;
        }
        self.tick += 1;
        let tick = self.tick;
        if let Some(old) = self.map.insert(key.clone(), Slot { value, bytes, last_used: tick }) {
            self.order.remove(&old.last_used);
            self.bytes -= old.bytes;
            out.replaced = Some(old.value);
        }
        self.order.insert(tick, key);
        self.bytes += bytes;
        while self.bytes > self.capacity {
            // The smallest tick is the LRU entry. It can never be the
            // key just inserted (which holds the newest tick) while the
            // loop runs: if everything else were already evicted, the
            // new entry alone fits (oversized values were rejected
            // above) and the loop condition fails first.
            let Some((_, victim)) = self.order.pop_first() else {
                break;
            };
            let slot = self.map.remove(&victim).unwrap();
            self.bytes -= slot.bytes;
            self.evictions += 1;
            out.evicted.push((victim, slot.value));
        }
        self.peak_bytes = self.peak_bytes.max(self.bytes);
        out
    }
}

impl<K, V> std::fmt::Debug for ByteLru<K, V> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ByteLru")
            .field("len", &self.map.len())
            .field("bytes", &self.bytes)
            .field("capacity", &self.capacity)
            .field("evictions", &self.evictions)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eviction_order_is_least_recently_used() {
        let mut lru: ByteLru<&str, u32> = ByteLru::new(30);
        assert!(lru.insert("a", 1, 10).evicted.is_empty());
        assert!(lru.insert("b", 2, 10).evicted.is_empty());
        assert!(lru.insert("c", 3, 10).evicted.is_empty());
        // Touch "a" so "b" becomes the oldest.
        assert_eq!(lru.get(&"a"), Some(&1));
        let ins = lru.insert("d", 4, 10);
        assert_eq!(ins.evicted.len(), 1);
        assert_eq!(ins.evicted[0], ("b", 2));
        assert!(lru.contains(&"a"), "recently touched survives");
        assert!(lru.contains(&"c"));
        assert!(lru.contains(&"d"));
        assert_eq!(lru.evictions(), 1);
    }

    #[test]
    fn multi_entry_eviction_drains_oldest_first() {
        let mut lru: ByteLru<u32, u32> = ByteLru::new(30);
        lru.insert(1, 1, 10);
        lru.insert(2, 2, 10);
        lru.insert(3, 3, 10);
        // A 30-byte value needs every older entry gone.
        let ins = lru.insert(4, 4, 30);
        assert_eq!(ins.evicted, vec![(1, 1), (2, 2), (3, 3)], "oldest first");
        assert_eq!(lru.len(), 1);
        assert_eq!(lru.bytes(), 30);
        assert_eq!(lru.evictions(), 3);
    }

    #[test]
    fn byte_accounting_on_insert_update_remove() {
        let mut lru: ByteLru<&str, u32> = ByteLru::new(100);
        lru.insert("a", 1, 40);
        assert_eq!(lru.bytes(), 40);
        // Same-key update replaces the old weight, not adds to it.
        let ins = lru.insert("a", 2, 25);
        assert_eq!(ins.replaced, Some(1));
        assert!(ins.evicted.is_empty());
        assert_eq!(lru.bytes(), 25);
        assert_eq!(lru.len(), 1);
        assert_eq!(lru.remove(&"a"), Some(2));
        assert_eq!(lru.bytes(), 0);
        assert!(lru.is_empty());
        assert_eq!(lru.evictions(), 0, "updates and removes are not evictions");
    }

    #[test]
    fn oversized_value_is_rejected_not_admitted() {
        let mut lru: ByteLru<&str, u32> = ByteLru::new(64);
        lru.insert("small", 1, 10);
        let ins = lru.insert("huge", 2, 65);
        assert_eq!(ins.rejected, Some(2));
        assert!(ins.evicted.is_empty(), "resident entries untouched");
        assert!(lru.contains(&"small"));
        assert_eq!(lru.bytes(), 10);
        assert_eq!(lru.evictions(), 0);
    }

    #[test]
    fn zero_capacity_disables_the_cache() {
        let mut lru: ByteLru<u32, u32> = ByteLru::new(0);
        let ins = lru.insert(1, 1, 1);
        assert_eq!(ins.rejected, Some(1));
        assert!(lru.is_empty());
        assert_eq!(lru.bytes(), 0);
        // Even a zero-weight entry fits a zero budget: bytes <= capacity.
        let ins = lru.insert(2, 2, 0);
        assert!(ins.rejected.is_none());
        assert_eq!(lru.len(), 1);
    }

    #[test]
    fn tiny_capacity_holds_exactly_one_entry() {
        let mut lru: ByteLru<u32, u32> = ByteLru::new(1);
        assert!(lru.insert(1, 10, 1).evicted.is_empty());
        let ins = lru.insert(2, 20, 1);
        assert_eq!(ins.evicted, vec![(1, 10)]);
        assert_eq!(lru.len(), 1);
        assert_eq!(lru.bytes(), 1);
    }

    #[test]
    fn peek_does_not_refresh_recency() {
        let mut lru: ByteLru<&str, u32> = ByteLru::new(20);
        lru.insert("a", 1, 10);
        lru.insert("b", 2, 10);
        assert_eq!(lru.peek(&"a"), Some(&1));
        // "a" is still the oldest: it goes, not "b".
        let ins = lru.insert("c", 3, 10);
        assert_eq!(ins.evicted, vec![("a", 1)]);
    }

    #[test]
    fn peak_bytes_is_a_high_water_mark() {
        let mut lru: ByteLru<u32, u32> = ByteLru::new(100);
        lru.insert(1, 1, 60);
        lru.insert(2, 2, 30);
        assert_eq!(lru.peak_bytes(), 90);
        lru.remove(&1);
        assert_eq!(lru.bytes(), 30);
        assert_eq!(lru.peak_bytes(), 90, "peak survives shrinking");
        // Inserts that evict never push the peak past capacity.
        lru.insert(3, 3, 80);
        assert!(lru.peak_bytes() <= 110);
    }

    #[test]
    fn pop_lru_evicts_oldest_and_counts() {
        let mut lru: ByteLru<&str, u32> = ByteLru::new(100);
        lru.insert("a", 1, 10);
        lru.insert("b", 2, 20);
        assert_eq!(lru.get(&"a"), Some(&1), "refresh a: b is now oldest");
        assert_eq!(lru.pop_lru(), Some(("b", 2)));
        assert_eq!(lru.bytes(), 10);
        assert_eq!(lru.evictions(), 1, "forced pops are evictions");
        assert_eq!(lru.pop_lru(), Some(("a", 1)));
        assert_eq!(lru.pop_lru(), None);
        assert!(lru.is_empty());
        assert_eq!(lru.bytes(), 0);
    }

    #[test]
    fn counters_track_every_eviction() {
        let mut lru: ByteLru<u32, u32> = ByteLru::new(10);
        for i in 0..5u32 {
            lru.insert(i, i, 10);
        }
        assert_eq!(lru.evictions(), 4, "each insert evicted its predecessor");
        assert_eq!(lru.len(), 1);
        assert!(lru.contains(&4));
    }
}
