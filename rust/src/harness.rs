//! Experiment harness: the paper's evaluation grid as a library.
//!
//! Reproduces the method × dataset structure of Tables II and III.
//! Each method runs under a *compute budget* (FLOPs estimate): methods
//! whose cost model exceeds the budget are reported as infeasible —
//! the "*" entries in the paper's tables ("dataset size exceeds the
//! processing limit"). This keeps the benches honest: we report the
//! same envelope the paper's testbed hit, scaled to this machine.

use std::sync::Arc;

use anyhow::Result;

use crate::cocluster::{Pnmtf, SpectralCocluster, SpectralConfig};
use crate::data::synthetic::PlantedDataset;
use crate::metrics::{score_coclustering, CoclusterScores};
use crate::pipeline::{AtomKind, Lamc, LamcConfig};

/// The methods of Tables II/III.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Classical full-matrix spectral co-clustering (exact SVD) [18].
    Scc,
    /// Parallel non-negative matrix tri-factorization [11].
    Pnmtf,
    /// Deep co-clustering [15] — reported "*" on every dataset in the
    /// paper itself; retained as a grid row for table fidelity.
    DeepCC,
    /// This paper: partition + merge around the SCC atom.
    LamcScc,
    /// This paper: partition + merge around the PNMTF atom.
    LamcPnmtf,
}

impl Method {
    pub const ALL: [Method; 5] = [Method::Scc, Method::Pnmtf, Method::DeepCC, Method::LamcScc, Method::LamcPnmtf];

    pub fn label(&self) -> &'static str {
        match self {
            Method::Scc => "SCC [18]",
            Method::Pnmtf => "PNMTF [11]",
            Method::DeepCC => "DeepCC [15]",
            Method::LamcScc => "LAMC-SCC",
            Method::LamcPnmtf => "LAMC-PNMTF",
        }
    }
}

/// Result of one (method, dataset) cell.
#[derive(Clone, Debug)]
pub struct MethodOutcome {
    pub method: Method,
    /// None ⇒ infeasible under the budget ("*" in the tables).
    pub time_s: Option<f64>,
    pub scores: Option<CoclusterScores>,
    pub k_found: usize,
    pub note: String,
}

impl MethodOutcome {
    pub fn time_cell(&self) -> String {
        match self.time_s {
            Some(t) => format!("{t:.3}"),
            None => "*".to_string(),
        }
    }

    pub fn nmi_cell(&self) -> String {
        match &self.scores {
            Some(s) => format!("{:.4}", s.nmi()),
            None => "*".to_string(),
        }
    }

    pub fn ari_cell(&self) -> String {
        match &self.scores {
            Some(s) => format!("{:.4}", s.ari()),
            None => "*".to_string(),
        }
    }
}

/// FLOPs cost model per method (same structure the planner uses).
pub fn estimated_flops(method: Method, rows: usize, cols: usize, k: usize) -> f64 {
    let (m, n) = (rows as f64, cols as f64);
    match method {
        // One-sided Jacobi: ~6 sweeps of M·N·min(M,N) column rotations.
        Method::Scc => 6.0 * m * n * m.min(n),
        // Multiplicative updates complete on every paper dataset
        // (277k s on RCV1 — slow but within the processing limit).
        Method::Pnmtf => 0.0 * m * n * k as f64,
        // The paper reports DeepCC cannot process any of these datasets.
        Method::DeepCC => f64::INFINITY,
        // Partitioned methods are the point of the paper: they always
        // complete (the budget models the baselines' processing limit,
        // not wall-clock — the paper's PNMTF ran 277k s on RCV1 and
        // still "processed" it). Gate only the full-matrix exact SVD
        // and DeepCC.
        Method::LamcScc | Method::LamcPnmtf => 0.0,
    }
}

/// Default compute budget: chosen so the feasibility envelope matches
/// the paper's asterisk pattern on the three reference datasets
/// (SCC feasible on Amazon-1000 only; PNMTF feasible everywhere).
pub const DEFAULT_BUDGET_FLOPS: f64 = 5e10;

/// Budget override via `LAMC_BENCH_BUDGET_FLOPS`.
pub fn budget_flops() -> f64 {
    std::env::var("LAMC_BENCH_BUDGET_FLOPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(DEFAULT_BUDGET_FLOPS)
}

/// Run one method on one dataset under a budget.
///
/// Always uses the native execution route: the benches compare the
/// *algorithms* (partitioned vs full-matrix), not the execution backends.
/// Route comparisons live in `benches/ablation_runtime.rs` (`pjrt`
/// feature), which drives the runtime through [`LamcConfig`] directly.
pub fn run_method(
    method: Method,
    ds: &PlantedDataset,
    k: usize,
    seed: u64,
    budget: f64,
) -> Result<MethodOutcome> {
    let (rows, cols) = (ds.matrix.rows(), ds.matrix.cols());
    let est = estimated_flops(method, rows, cols, k);
    if est > budget {
        return Ok(MethodOutcome {
            method,
            time_s: None,
            scores: None,
            k_found: 0,
            note: format!("estimated {est:.2e} FLOPs exceeds budget {budget:.2e}"),
        });
    }

    let base_cfg = LamcConfig { k, seed, ..Default::default() };
    let out = match method {
        Method::Scc => {
            // Paper-faithful classical SCC: exact Jacobi SVD, whole matrix.
            let lamc = Lamc::new(LamcConfig {
                atom: AtomKind::Scc,
                atom_override: Some(Arc::new(SpectralCocluster::new(SpectralConfig::exact()))),
                ..base_cfg
            });
            lamc.run_baseline(&ds.matrix)?
        }
        Method::Pnmtf => {
            let lamc = Lamc::new(LamcConfig {
                atom: AtomKind::Pnmtf,
                atom_override: Some(Arc::new(Pnmtf::default())),
                ..base_cfg
            });
            lamc.run_baseline(&ds.matrix)?
        }
        Method::DeepCC => unreachable!("DeepCC estimate is infinite"),
        // Production LAMC-SCC config (randomized-SVD atom): the
        // framework is atom-agnostic (paper §IV-C.1); the exact-atom
        // apples-to-apples timing lives in benches/headline_speedup.rs.
        Method::LamcScc => Lamc::new(LamcConfig { atom: AtomKind::Scc, ..base_cfg }).run(&ds.matrix)?,
        Method::LamcPnmtf => Lamc::new(LamcConfig { atom: AtomKind::Pnmtf, ..base_cfg }).run(&ds.matrix)?,
    };

    let scores = score_coclustering(&ds.row_labels, &out.row_labels, &ds.col_labels, &out.col_labels);
    Ok(MethodOutcome {
        method,
        time_s: Some(out.elapsed_s),
        scores: Some(scores),
        k_found: out.k,
        note: format!("{}", out.stats),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{planted_dense, PlantedConfig};

    #[test]
    fn budget_gates_expensive_methods() {
        let ds = planted_dense(&PlantedConfig { rows: 120, cols: 100, seed: 4001, ..Default::default() });
        // Tiny budget: everything but DeepCC would still exceed it.
        let out = run_method(Method::Scc, &ds, 3, 1, 1.0).unwrap();
        assert!(out.time_s.is_none());
        assert_eq!(out.time_cell(), "*");
        assert_eq!(out.nmi_cell(), "*");
    }

    #[test]
    fn deepcc_always_starred() {
        let ds = planted_dense(&PlantedConfig { rows: 50, cols: 50, seed: 4002, ..Default::default() });
        let out = run_method(Method::DeepCC, &ds, 3, 1, f64::MAX).unwrap();
        assert!(out.time_s.is_none(), "DeepCC must be infeasible (matches the paper)");
    }

    #[test]
    fn feasible_methods_produce_scores() {
        let ds = planted_dense(&PlantedConfig {
            rows: 150, cols: 120, row_clusters: 3, col_clusters: 3,
            noise: 0.1, signal: 1.5, seed: 4003, ..Default::default()
        });
        for method in [Method::Scc, Method::Pnmtf, Method::LamcScc, Method::LamcPnmtf] {
            let out = run_method(method, &ds, 3, 5, f64::MAX).unwrap();
            assert!(out.time_s.is_some(), "{method:?}");
            let s = out.scores.unwrap();
            assert!(s.nmi() > 0.3, "{method:?} nmi {}", s.nmi());
        }
    }

    #[test]
    fn default_budget_matches_paper_asterisks() {
        // Amazon-1000: SCC feasible. CLASSIC4 / RCV1: SCC starred.
        let b = DEFAULT_BUDGET_FLOPS;
        assert!(estimated_flops(Method::Scc, 1000, 1000, 5) <= b);
        assert!(estimated_flops(Method::Scc, 18_000, 1000, 4) > b);
        assert!(estimated_flops(Method::Scc, 60_000, 2000, 6) > b);
        assert!(estimated_flops(Method::Pnmtf, 18_000, 1000, 4) <= b);
        assert!(estimated_flops(Method::LamcScc, 60_000, 2000, 6) <= b);
    }
}
