//! Planted co-cluster generators.
//!
//! A planted dataset draws row labels `u ∈ {0..k}` and column labels
//! `v ∈ {0..d}`, assigns each (row-cluster, col-cluster) cell a signal
//! level, and then emits either dense Gaussian data around the cell means
//! or sparse Bernoulli data with cell-dependent firing rates. Rows and
//! columns are shuffled so no algorithm can exploit ordering.

use crate::matrix::{CsrMatrix, DenseMatrix, Matrix};
use crate::rng::Xoshiro256;

/// Configuration for a planted co-cluster problem.
#[derive(Clone, Debug)]
pub struct PlantedConfig {
    pub rows: usize,
    pub cols: usize,
    /// Number of row clusters (k in the paper).
    pub row_clusters: usize,
    /// Number of column clusters (d in the paper).
    pub col_clusters: usize,
    /// Dense: noise stddev around cell means. Sparse: background rate.
    pub noise: f64,
    /// Dense: separation between cell means. Sparse: in-block rate boost.
    pub signal: f64,
    /// Target density for sparse generation (fraction of nnz).
    pub density: f64,
    pub seed: u64,
}

impl Default for PlantedConfig {
    fn default() -> Self {
        Self {
            rows: 200,
            cols: 160,
            row_clusters: 4,
            col_clusters: 4,
            noise: 0.3,
            signal: 1.0,
            density: 0.02,
            seed: 0xC0C1,
        }
    }
}

/// A generated problem instance with ground truth.
#[derive(Clone, Debug)]
pub struct PlantedDataset {
    pub matrix: Matrix,
    pub row_labels: Vec<usize>,
    pub col_labels: Vec<usize>,
    pub config: PlantedConfig,
}

/// Balanced-but-jittered label assignment, then shuffled.
fn draw_labels(n: usize, k: usize, rng: &mut Xoshiro256) -> Vec<usize> {
    assert!(k >= 1 && n >= k, "need at least one item per cluster");
    // Guarantee every cluster non-empty, then fill uniformly.
    let mut labels: Vec<usize> = (0..k).collect();
    labels.extend((k..n).map(|_| rng.next_below(k)));
    rng.shuffle(&mut labels);
    labels
}

/// Cell signal table: block-diagonal-dominant pattern (the visualizable
/// structure in the paper's Fig. 1b), with off-diagonal cells at
/// distinct low levels so column clusters are identifiable even when
/// k ≠ d.
fn cell_mean(ru: usize, cv: usize, k: usize, d: usize, signal: f64) -> f64 {
    if ru % d.min(k) == cv % d.min(k) {
        signal * (1.0 + 0.25 * ru as f64)
    } else {
        0.15 * signal * (((ru * 31 + cv * 17) % 7) as f64 / 7.0)
    }
}

/// Dense planted problem: `a_ij ~ N(mean(u_i, v_j), noise²)`, shifted to
/// be non-negative (co-clustering inputs are bipartite adjacency weights).
pub fn planted_dense(config: &PlantedConfig) -> PlantedDataset {
    let mut rng = Xoshiro256::seed_from(config.seed);
    let row_labels = draw_labels(config.rows, config.row_clusters, &mut rng);
    let col_labels = draw_labels(config.cols, config.col_clusters, &mut rng);
    let mut m = DenseMatrix::zeros(config.rows, config.cols);
    for i in 0..config.rows {
        let ru = row_labels[i];
        let row = m.row_mut(i);
        for (j, x) in row.iter_mut().enumerate() {
            let mean = cell_mean(ru, col_labels[j], config.row_clusters, config.col_clusters, config.signal);
            let val = mean + config.noise * rng.next_normal();
            *x = val.max(0.0) as f32;
        }
    }
    PlantedDataset {
        matrix: Matrix::Dense(m),
        row_labels,
        col_labels,
        config: config.clone(),
    }
}

/// Sparse planted problem: entry (i,j) is stored with probability
/// `p_in` when (u_i, v_j) is a signal cell and `p_out` otherwise, with
/// magnitudes ~ 1 + Exp-ish tail (Zipf-flavoured tf weights).
pub fn planted_sparse(config: &PlantedConfig) -> PlantedDataset {
    let mut rng = Xoshiro256::seed_from(config.seed);
    let row_labels = draw_labels(config.rows, config.row_clusters, &mut rng);
    let col_labels = draw_labels(config.cols, config.col_clusters, &mut rng);
    // Split the density budget: signal cells get `signal`× the background
    // rate. Compute rates so overall expected density ≈ config.density.
    let k = config.row_clusters;
    let d = config.col_clusters;
    let diag_frac = 1.0 / d.min(k) as f64; // fraction of cells that carry signal
    let boost = (config.signal.max(1.0)) * 8.0;
    let p_out = config.density / (diag_frac * boost + (1.0 - diag_frac));
    let p_in = (p_out * boost).min(0.9);
    let mut triplets = Vec::with_capacity((config.rows as f64 * config.cols as f64 * config.density * 1.2) as usize);
    for i in 0..config.rows {
        let ru = row_labels[i];
        for j in 0..config.cols {
            let cv = col_labels[j];
            let in_block = ru % d.min(k) == cv % d.min(k);
            let p = if in_block { p_in } else { p_out };
            if rng.next_f64() < p {
                // tf-like magnitude: mostly 1, occasional heavier counts.
                let mag = 1.0 + (-(1.0 - rng.next_f64()).ln() * 1.5).floor();
                triplets.push((i, j, mag as f32));
            }
        }
    }
    let m = CsrMatrix::from_triplets(config.rows, config.cols, triplets);
    PlantedDataset {
        matrix: Matrix::Sparse(m),
        row_labels,
        col_labels,
        config: config.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_shape_and_determinism() {
        let cfg = PlantedConfig { rows: 50, cols: 40, seed: 1, ..Default::default() };
        let a = planted_dense(&cfg);
        let b = planted_dense(&cfg);
        assert_eq!(a.matrix.rows(), 50);
        assert_eq!(a.matrix.cols(), 40);
        assert_eq!(a.row_labels, b.row_labels);
        assert_eq!(a.matrix.to_dense().data(), b.matrix.to_dense().data());
    }

    #[test]
    fn labels_cover_all_clusters() {
        let cfg = PlantedConfig { rows: 30, cols: 30, row_clusters: 5, col_clusters: 3, ..Default::default() };
        let ds = planted_dense(&cfg);
        for c in 0..5 {
            assert!(ds.row_labels.contains(&c));
        }
        for c in 0..3 {
            assert!(ds.col_labels.contains(&c));
        }
    }

    #[test]
    fn dense_signal_blocks_have_higher_mean() {
        let cfg = PlantedConfig { rows: 120, cols: 120, noise: 0.1, signal: 2.0, seed: 3, ..Default::default() };
        let ds = planted_dense(&cfg);
        let m = ds.matrix.to_dense();
        let (mut in_sum, mut in_n, mut out_sum, mut out_n) = (0.0f64, 0usize, 0.0f64, 0usize);
        for i in 0..120 {
            for j in 0..120 {
                let in_block = ds.row_labels[i] % 4 == ds.col_labels[j] % 4;
                if in_block {
                    in_sum += m.get(i, j) as f64;
                    in_n += 1;
                } else {
                    out_sum += m.get(i, j) as f64;
                    out_n += 1;
                }
            }
        }
        assert!(in_sum / in_n as f64 > 3.0 * (out_sum / out_n as f64));
    }

    #[test]
    fn sparse_density_near_target() {
        let cfg = PlantedConfig {
            rows: 400,
            cols: 300,
            density: 0.05,
            seed: 4,
            ..Default::default()
        };
        let ds = planted_sparse(&cfg);
        if let Matrix::Sparse(s) = &ds.matrix {
            let d = s.density();
            assert!((d - 0.05).abs() < 0.02, "density {d}");
        } else {
            panic!("expected sparse");
        }
    }

    #[test]
    fn sparse_in_block_rate_exceeds_background() {
        let cfg = PlantedConfig { rows: 200, cols: 200, density: 0.05, seed: 5, ..Default::default() };
        let ds = planted_sparse(&cfg);
        let m = ds.matrix.to_dense();
        let (mut in_nnz, mut in_n, mut out_nnz, mut out_n) = (0usize, 0usize, 0usize, 0usize);
        for i in 0..200 {
            for j in 0..200 {
                let in_block = ds.row_labels[i] % 4 == ds.col_labels[j] % 4;
                let nz = (m.get(i, j) != 0.0) as usize;
                if in_block {
                    in_nnz += nz;
                    in_n += 1;
                } else {
                    out_nnz += nz;
                    out_n += 1;
                }
            }
        }
        let rate_in = in_nnz as f64 / in_n as f64;
        let rate_out = out_nnz as f64 / out_n as f64;
        assert!(rate_in > 4.0 * rate_out, "in {rate_in} out {rate_out}");
    }
}
