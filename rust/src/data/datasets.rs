//! Named dataset builders mirroring the paper's evaluation corpora.
//!
//! Shapes and sparsity regimes match Table II's three workloads (scaled
//! per DESIGN.md §4 where noted). Each returns a [`PlantedDataset`]
//! carrying ground-truth row/column labels for Table III scoring.

use super::synthetic::{planted_dense, planted_sparse, PlantedConfig, PlantedDataset};

/// Descriptor used by the CLI/benches to enumerate workloads.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub rows: usize,
    pub cols: usize,
    pub sparse: bool,
    pub row_clusters: usize,
    pub col_clusters: usize,
}

pub const SPECS: &[DatasetSpec] = &[
    DatasetSpec { name: "amazon1000", rows: 1000, cols: 1000, sparse: false, row_clusters: 5, col_clusters: 5 },
    DatasetSpec { name: "classic4", rows: 18_000, cols: 1000, sparse: true, row_clusters: 4, col_clusters: 4 },
    DatasetSpec { name: "rcv1_large", rows: 60_000, cols: 2000, sparse: true, row_clusters: 6, col_clusters: 6 },
];

/// Look up a spec by name.
pub fn spec(name: &str) -> Option<&'static DatasetSpec> {
    SPECS.iter().find(|s| s.name == name)
}

/// Build a dataset by spec name with an optional row-count override
/// (used to scale experiments to a time budget).
pub fn build(name: &str, scale_rows: Option<usize>, seed: u64) -> Option<PlantedDataset> {
    let s = spec(name)?;
    let rows = scale_rows.unwrap_or(s.rows);
    // Row count scales for time-budgeted runs; the column space (the
    // vocabulary, for text workloads) keeps its full width — shrinking
    // it would change the per-row signal density, not just the size.
    let cols = s.cols;
    let cfg = PlantedConfig {
        rows,
        cols,
        row_clusters: s.row_clusters,
        col_clusters: s.col_clusters,
        seed,
        ..if s.sparse {
            PlantedConfig { noise: 0.0, signal: 3.0, density: 0.03, ..Default::default() }
        } else {
            PlantedConfig { noise: 0.35, signal: 1.2, ..Default::default() }
        }
    };
    Some(if s.sparse { planted_sparse(&cfg) } else { planted_dense(&cfg) })
}

/// Amazon-1000 equivalent: 1000×1000 dense review-feature matrix,
/// 5 planted customer-behaviour co-clusters.
pub fn amazon1000(seed: u64) -> PlantedDataset {
    build("amazon1000", None, seed).unwrap()
}

/// CLASSIC4 equivalent: 18000×1000 sparse document–term matrix,
/// 4 planted topics, ~1.5% density.
pub fn classic4(seed: u64) -> PlantedDataset {
    build("classic4", None, seed).unwrap()
}

/// RCV1-Large equivalent (scaled to this testbed): 60000×2000 sparse,
/// 6 planted topic groups. Override rows via [`build`] to go bigger.
pub fn rcv1_large(seed: u64) -> PlantedDataset {
    build("rcv1_large", None, seed).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_resolve() {
        assert!(spec("amazon1000").is_some());
        assert!(spec("classic4").is_some());
        assert!(spec("rcv1_large").is_some());
        assert!(spec("nope").is_none());
    }

    #[test]
    fn amazon_is_dense_1000sq() {
        let ds = amazon1000(7);
        assert_eq!(ds.matrix.rows(), 1000);
        assert_eq!(ds.matrix.cols(), 1000);
        assert!(!ds.matrix.is_sparse());
    }

    #[test]
    fn classic4_is_sparse_with_four_topics() {
        let ds = build("classic4", Some(900), 7).unwrap();
        assert!(ds.matrix.is_sparse());
        assert_eq!(ds.config.row_clusters, 4);
        let density = ds.matrix.nnz() as f64 / (ds.matrix.rows() as f64 * ds.matrix.cols() as f64);
        assert!(density < 0.1, "density {density}");
    }

    #[test]
    fn scaling_preserves_cluster_counts() {
        let ds = build("rcv1_large", Some(1200), 7).unwrap();
        assert_eq!(ds.matrix.rows(), 1200);
        assert_eq!(ds.config.row_clusters, 6);
        for c in 0..6 {
            assert!(ds.row_labels.contains(&c));
        }
    }
}
