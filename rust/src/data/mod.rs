//! Dataset substrate.
//!
//! The paper evaluates on Amazon-1000, CLASSIC4 and RCV1-Large. Those
//! corpora (and their preprocessing pipelines) are not shipped in this
//! image, so `datasets.rs` provides synthetic equivalents with *planted*
//! co-cluster structure at matching shapes/sparsity — which is exactly
//! what NMI/ARI evaluation needs (ground-truth labels). See DESIGN.md §4
//! for the substitution argument.

pub mod datasets;
pub mod synthetic;

pub use datasets::{amazon1000, classic4, rcv1_large, DatasetSpec};
pub use synthetic::{planted_dense, planted_sparse, PlantedConfig, PlantedDataset};
