//! # LAMC — Large-scale Adaptive Matrix Co-clustering
//!
//! Reproduction of *"Scalable Co-Clustering for Large-Scale Data through
//! Dynamic Partitioning and Hierarchical Merging"* (Wu, Huang & Yan,
//! IEEE SMC 2024) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the coordinator: probabilistic partition
//!   planning ([`partition`]), a leader/worker scheduler that fans block
//!   co-clustering jobs out across a persistent thread pool and execution
//!   routes ([`coordinator`]), hierarchical co-cluster merging
//!   ([`merge`]), a chunked on-disk matrix store for out-of-core inputs
//!   ([`store`]), and a long-lived TCP serving layer with a job queue
//!   and result cache ([`service`]).
//! * **Layer 2** — a JAX compute graph per partition block (spectral
//!   co-clustering embedding + k-means), AOT-lowered to HLO text at build
//!   time and executed from Rust via PJRT (the `runtime` module, compiled
//!   only with the off-by-default `pjrt` cargo feature).
//! * **Layer 1** — Pallas kernels for the block hot-spots (bipartite
//!   normalization, subspace-iteration matmuls, k-means assignment),
//!   inlined into the Layer-2 HLO.
//!
//! The default build has **zero native/XLA dependencies**: every block
//! runs on the pure-Rust native route. With `--features pjrt`, Python
//! still never runs on the request path — `make artifacts` compiles the
//! HLO once; the `lamc` binary and examples are self-contained after.
//!
//! ## Quickstart
//!
//! ```
//! use lamc::data::synthetic::{planted_dense, PlantedConfig};
//! use lamc::pipeline::{Lamc, LamcConfig};
//!
//! // A small dense matrix with 3 planted co-clusters.
//! let ds = planted_dense(&PlantedConfig {
//!     rows: 120, cols: 100, row_clusters: 3, col_clusters: 3,
//!     noise: 0.1, signal: 1.5, seed: 7, ..Default::default()
//! });
//!
//! let result = Lamc::new(LamcConfig { k: 3, ..Default::default() })
//!     .run(&ds.matrix)
//!     .unwrap();
//! assert_eq!(result.row_labels.len(), 120);
//! assert_eq!(result.col_labels.len(), 100);
//!
//! let scores = lamc::metrics::score_coclustering(
//!     &ds.row_labels, &result.row_labels,
//!     &ds.col_labels, &result.col_labels);
//! println!("NMI {:.4}  ARI {:.4}", scores.nmi(), scores.ari());
//! ```
//!
//! The paper-shaped workloads run through the same call — `no_run` here
//! only because they take seconds, not because the API differs:
//!
//! ```no_run
//! use lamc::data;
//! use lamc::pipeline::{Lamc, LamcConfig};
//!
//! let ds = data::amazon1000(42); // 1000x1000 dense, 5 planted co-clusters
//! let result = Lamc::new(LamcConfig { k: 5, ..Default::default() })
//!     .run(&ds.matrix)
//!     .unwrap();
//! println!("found {} co-clusters in {:.3} s", result.k, result.elapsed_s);
//! ```

// Style lints this index-heavy numeric codebase trips by design; kept
// allowed so CI's `clippy -D warnings` gates on correctness lints.
#![allow(unknown_lints)]
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::ptr_arg,
    clippy::field_reassign_with_default
)]

pub mod bench_util;
pub mod cache;
pub mod cli;
pub mod cocluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod harness;
pub mod linalg;
pub mod logging;
pub mod matrix;
pub mod merge;
pub mod metrics;
pub mod partition;
pub mod pipeline;
pub mod rng;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod service;
pub mod store;
pub mod testkit;
pub mod trace;

pub use coordinator::RunOptions;
pub use pipeline::{Lamc, LamcConfig, LamcResult, RunBasis};
