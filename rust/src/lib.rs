//! # LAMC — Large-scale Adaptive Matrix Co-clustering
//!
//! Reproduction of *"Scalable Co-Clustering for Large-Scale Data through
//! Dynamic Partitioning and Hierarchical Merging"* (Wu, Huang & Yan,
//! IEEE SMC 2024) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the coordinator: probabilistic partition
//!   planning ([`partition`]), a leader/worker scheduler that fans block
//!   co-clustering jobs out across threads and execution routes
//!   ([`coordinator`]), and hierarchical co-cluster merging ([`merge`]).
//! * **Layer 2** — a JAX compute graph per partition block (spectral
//!   co-clustering embedding + k-means), AOT-lowered to HLO text at build
//!   time and executed from Rust via PJRT ([`runtime`]).
//! * **Layer 1** — Pallas kernels for the block hot-spots (bipartite
//!   normalization, subspace-iteration matmuls, k-means assignment),
//!   inlined into the Layer-2 HLO.
//!
//! Python never runs on the request path: `make artifacts` compiles the
//! HLO once; the `lamc` binary and examples are self-contained after.
//!
//! ## Quickstart
//!
//! ```no_run
//! use lamc::data;
//! use lamc::pipeline::{Lamc, LamcConfig};
//!
//! let ds = data::amazon1000(42);
//! let result = Lamc::new(LamcConfig::default()).run(&ds.matrix).unwrap();
//! let scores = lamc::metrics::score_coclustering(
//!     &ds.row_labels, &result.row_labels,
//!     &ds.col_labels, &result.col_labels);
//! println!("NMI {:.4}  ARI {:.4}", scores.nmi(), scores.ari());
//! ```

pub mod bench_util;
pub mod cli;
pub mod cocluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod harness;
pub mod linalg;
pub mod logging;
pub mod matrix;
pub mod merge;
pub mod metrics;
pub mod partition;
pub mod pipeline;
pub mod rng;
pub mod runtime;
pub mod testkit;

pub use pipeline::{Lamc, LamcConfig, LamcResult};
