//! Shuffled grid-partition sampler (paper §IV-B.3: `T_p` independent
//! random re-partitions of the shuffled matrix).
//!
//! Each of the `T_p` sampling rounds draws independent uniform
//! permutations of rows and columns, then cuts the permuted matrix into
//! the planner's `m×n` grid. A [`BlockJob`] carries the *global* indices
//! of its rows/columns so results can be mapped straight back without
//! storing the permutations.

use crate::rng::Xoshiro256;

use super::planner::PartitionPlan;

/// One block co-clustering job.
#[derive(Clone, Debug)]
pub struct BlockJob {
    /// Sampling round this job belongs to (0-based).
    pub round: usize,
    /// Grid coordinates within the round.
    pub grid: (usize, usize),
    /// Global row ids covered by this block.
    pub rows: Vec<usize>,
    /// Global column ids covered by this block.
    pub cols: Vec<usize>,
}

impl BlockJob {
    pub fn shape(&self) -> (usize, usize) {
        (self.rows.len(), self.cols.len())
    }
}

/// All blocks of one sampling round.
#[derive(Clone, Debug)]
pub struct SamplingRound {
    pub round: usize,
    pub jobs: Vec<BlockJob>,
}

/// Materialize `T_p` rounds of shuffled grid partitions.
///
/// Every round covers every row and column exactly once (verified by the
/// property tests): the union of a round's blocks is a partition of the
/// index space, which is what makes the merge step's intra-round
/// co-clusters disjoint.
pub fn sample_partition(rows: usize, cols: usize, plan: &PartitionPlan, rng: &mut Xoshiro256) -> Vec<SamplingRound> {
    let mut rounds = Vec::with_capacity(plan.t_p);
    for round in 0..plan.t_p {
        let rp = rng.permutation(rows);
        let cp = rng.permutation(cols);
        let mut jobs = Vec::with_capacity(plan.m * plan.n);
        for bi in 0..plan.m {
            let r_lo = bi * plan.phi;
            let r_hi = ((bi + 1) * plan.phi).min(rows);
            if r_lo >= r_hi {
                continue;
            }
            for bj in 0..plan.n {
                let c_lo = bj * plan.psi;
                let c_hi = ((bj + 1) * plan.psi).min(cols);
                if c_lo >= c_hi {
                    continue;
                }
                jobs.push(BlockJob {
                    round,
                    grid: (bi, bj),
                    rows: rp[r_lo..r_hi].to_vec(),
                    cols: cp[c_lo..c_hi].to_vec(),
                });
            }
        }
        rounds.push(SamplingRound { round, jobs });
    }
    rounds
}

/// [`sample_partition`] for a [`crate::store::MatrixView`]: sampling
/// draws index permutations only, so a store-backed matrix is sampled
/// without reading any data — the scheduler's per-block gathers are the
/// first (and only) place chunk payloads are touched.
pub fn sample_partition_view(
    matrix: crate::store::MatrixView<'_>,
    plan: &PartitionPlan,
    rng: &mut Xoshiro256,
) -> Vec<SamplingRound> {
    sample_partition(matrix.rows(), matrix.cols(), plan, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::planner::{plan, PlannerConfig};

    fn mkplan(phi: usize, psi: usize, m: usize, n: usize, t_p: usize) -> PartitionPlan {
        PartitionPlan { phi, psi, m, n, t_p, certified_probability: 1.0, estimated_cost: 0.0 }
    }

    #[test]
    fn each_round_partitions_index_space() {
        let mut rng = Xoshiro256::seed_from(401);
        let p = mkplan(30, 25, 4, 4, 3);
        let rounds = sample_partition(100, 90, &p, &mut rng);
        assert_eq!(rounds.len(), 3);
        for round in &rounds {
            let mut row_seen = vec![false; 100];
            let mut col_count = vec![0usize; 90];
            for job in &round.jobs {
                for &r in &job.rows {
                    assert!(!row_seen[r] || job.grid.1 != 0, "row duplicated across block-rows");
                    row_seen[r] = true;
                }
                for &c in &job.cols {
                    col_count[c] += 1;
                }
            }
            assert!(row_seen.iter().all(|&s| s));
            // Every column appears once per block-row (m times total).
            assert!(col_count.iter().all(|&c| c == 4), "{col_count:?}");
        }
    }

    #[test]
    fn block_shapes_respect_plan() {
        let mut rng = Xoshiro256::seed_from(402);
        let p = mkplan(32, 32, 4, 4, 1);
        let rounds = sample_partition(128, 128, &p, &mut rng);
        for job in &rounds[0].jobs {
            assert_eq!(job.shape(), (32, 32));
        }
        assert_eq!(rounds[0].jobs.len(), 16);
    }

    #[test]
    fn ragged_tail_blocks_are_smaller() {
        let mut rng = Xoshiro256::seed_from(403);
        let p = mkplan(50, 40, 3, 3, 1);
        let rounds = sample_partition(130, 100, &p, &mut rng);
        let shapes: Vec<(usize, usize)> = rounds[0].jobs.iter().map(|j| j.shape()).collect();
        // Last block-row has 130 − 2·50 = 30 rows; last block-col 100 − 2·40 = 20.
        assert!(shapes.contains(&(30, 20)));
        assert!(shapes.contains(&(50, 40)));
    }

    #[test]
    fn rounds_use_different_permutations() {
        let mut rng = Xoshiro256::seed_from(404);
        let p = mkplan(50, 50, 2, 2, 2);
        let rounds = sample_partition(100, 100, &p, &mut rng);
        assert_ne!(rounds[0].jobs[0].rows, rounds[1].jobs[0].rows);
    }

    #[test]
    fn planner_plan_produces_valid_jobs() {
        let mut rng = Xoshiro256::seed_from(405);
        let cfg = PlannerConfig::default();
        let pl = plan(1000, 800, &cfg);
        let rounds = sample_partition(1000, 800, &pl, &mut rng);
        assert_eq!(rounds.len(), pl.t_p);
        let blocks: usize = rounds.iter().map(|r| r.jobs.len()).sum();
        assert_eq!(blocks, pl.total_blocks());
    }
}
