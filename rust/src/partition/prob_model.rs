//! Theorem 1: probabilistic co-cluster detection model (paper §III
//! problem formulation + §IV-B.1, Eqs. 1–4).
//!
//! Under a uniformly random row/column shuffle, the number of rows of a
//! co-cluster `C_k` that land in one `φ×ψ` block is hypergeometric; the
//! paper bounds the probability that a block holds fewer than `T_m` of
//! them by the Hoeffding-style tail `exp(-2 s² φ)` with
//! `s = M⁽ᵏ⁾/M − (T_m−1)/φ` (Eq. 12), and symmetrically for columns.
//! The probability that *no* block in an `m×n` grid detects the
//! co-cluster is then bounded by Eq. 2, and `T_p` independent shuffles
//! drive the miss probability down geometrically (Eq. 3).

/// Prior knowledge about the smallest co-cluster the run must detect:
/// its relative row/column masses, plus the atom detector's minimum
/// viable fragment (`T_m × T_n` entries inside one block).
#[derive(Clone, Copy, Debug)]
pub struct CoclusterPrior {
    /// `M⁽ᵏ⁾ / M`: fraction of all rows belonging to the co-cluster.
    pub row_fraction: f64,
    /// `N⁽ᵏ⁾ / N`: fraction of all columns.
    pub col_fraction: f64,
    /// `T_m`: minimum rows of the co-cluster a block must capture for the
    /// atom method to identify it.
    pub t_m: usize,
    /// `T_n`: minimum columns.
    pub t_n: usize,
}

impl Default for CoclusterPrior {
    fn default() -> Self {
        // Detect co-clusters holding ≥10% of rows/cols, needing ≥8×8
        // fragments — conservative for spectral atoms on text-scale data.
        Self { row_fraction: 0.10, col_fraction: 0.10, t_m: 8, t_n: 8 }
    }
}

/// `s⁽ᵏ⁾ = M⁽ᵏ⁾/M − (T_m−1)/φ` (Eq. 16). Negative ⇒ the block is too
/// small to ever hold a viable fragment: the bound is vacuous.
pub fn margin_rows(prior: &CoclusterPrior, phi: usize) -> f64 {
    prior.row_fraction - (prior.t_m.saturating_sub(1)) as f64 / phi as f64
}

/// `t⁽ᵏ⁾ = N⁽ᵏ⁾/N − (T_n−1)/ψ` (Eq. 16).
pub fn margin_cols(prior: &CoclusterPrior, psi: usize) -> f64 {
    prior.col_fraction - (prior.t_n.saturating_sub(1)) as f64 / psi as f64
}

/// Failure bound for one shuffled grid partition (Eq. 2 / 17):
/// `P(ω_k) ≤ exp{−2[φ·m·s² + ψ·n·t²]}`.
///
/// Returns 1.0 (vacuous bound) when either margin is non-positive.
pub fn failure_bound(prior: &CoclusterPrior, phi: usize, psi: usize, m: usize, n: usize) -> f64 {
    let s = margin_rows(prior, phi);
    let t = margin_cols(prior, psi);
    if s <= 0.0 || t <= 0.0 {
        return 1.0;
    }
    let exponent = -2.0 * ((phi * m) as f64 * s * s + (psi * n) as f64 * t * t);
    exponent.exp().min(1.0)
}

/// Detection probability after `T_p` independent samplings (Eq. 3):
/// `P ≥ 1 − P(ω_k)^{T_p}`.
pub fn detection_probability(prior: &CoclusterPrior, phi: usize, psi: usize, m: usize, n: usize, t_p: usize) -> f64 {
    let w = failure_bound(prior, phi, psi, m, n);
    1.0 - w.powi(t_p as i32)
}

/// Eq. 4 solver: smallest `T_p` with `1 − P(ω_k)^{T_p} ≥ P_thresh`.
/// `None` when the bound is vacuous (`P(ω_k) = 1`): no number of
/// samplings can certify detection for this configuration.
pub fn required_samplings(prior: &CoclusterPrior, phi: usize, psi: usize, m: usize, n: usize, p_thresh: f64) -> Option<usize> {
    assert!((0.0..1.0).contains(&p_thresh), "P_thresh must be in [0,1)");
    let w = failure_bound(prior, phi, psi, m, n);
    if w >= 1.0 {
        return None;
    }
    if w <= 0.0 {
        return Some(1);
    }
    // P(ω)^Tp ≤ 1 − P_thresh  ⇔  Tp ≥ ln(1−P_thresh)/ln(P(ω)).
    let t = ((1.0 - p_thresh).ln() / w.ln()).ceil();
    Some((t as usize).max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prior() -> CoclusterPrior {
        CoclusterPrior { row_fraction: 0.2, col_fraction: 0.2, t_m: 8, t_n: 8 }
    }

    #[test]
    fn margins_match_formula() {
        let p = prior();
        assert!((margin_rows(&p, 100) - (0.2 - 7.0 / 100.0)).abs() < 1e-12);
        assert!((margin_cols(&p, 70) - (0.2 - 7.0 / 70.0)).abs() < 1e-12);
    }

    #[test]
    fn failure_bound_decreases_with_more_blocks() {
        let p = prior();
        let b1 = failure_bound(&p, 100, 100, 2, 2);
        let b2 = failure_bound(&p, 100, 100, 4, 4);
        assert!(b2 < b1, "{b2} vs {b1}");
    }

    #[test]
    fn failure_bound_vacuous_for_tiny_blocks() {
        let p = prior();
        // φ = 20 ⇒ s = 0.2 − 7/20 < 0 ⇒ vacuous.
        assert_eq!(failure_bound(&p, 20, 100, 4, 4), 1.0);
    }

    #[test]
    fn detection_probability_monotone_in_tp() {
        let p = prior();
        let d1 = detection_probability(&p, 128, 128, 4, 4, 1);
        let d3 = detection_probability(&p, 128, 128, 4, 4, 3);
        let d9 = detection_probability(&p, 128, 128, 4, 4, 9);
        assert!(d1 <= d3 && d3 <= d9);
        assert!(d9 <= 1.0);
    }

    #[test]
    fn required_samplings_achieves_threshold() {
        let p = prior();
        for &thresh in &[0.5, 0.9, 0.99, 0.999] {
            let tp = required_samplings(&p, 64, 64, 4, 4, thresh);
            if let Some(tp) = tp {
                let achieved = detection_probability(&p, 64, 64, 4, 4, tp);
                assert!(achieved >= thresh, "tp={tp} achieved={achieved} thresh={thresh}");
                // Minimality: one fewer sampling must miss the threshold
                // (unless tp == 1).
                if tp > 1 {
                    let under = detection_probability(&p, 64, 64, 4, 4, tp - 1);
                    assert!(under < thresh, "tp not minimal");
                }
            }
        }
    }

    #[test]
    fn required_samplings_none_when_vacuous() {
        let p = prior();
        assert_eq!(required_samplings(&p, 10, 10, 4, 4, 0.9), None);
    }

    #[test]
    fn bound_dominates_monte_carlo_miss_rate() {
        // Empirical check of Theorem 1: simulate random shuffles and
        // count how often a planted co-cluster has < T_m rows AND < T_n
        // cols in every block. The theoretical bound must dominate.
        use crate::rng::Xoshiro256;
        let (m_total, n_total) = (200usize, 200usize);
        let p = CoclusterPrior { row_fraction: 0.25, col_fraction: 0.25, t_m: 6, t_n: 6, };
        let (phi, psi, m, n) = (50usize, 50usize, 4usize, 4usize);
        let bound = failure_bound(&p, phi, psi, m, n);
        let mut rng = Xoshiro256::seed_from(313);
        let rows_in = (m_total as f64 * p.row_fraction) as usize;
        let cols_in = (n_total as f64 * p.col_fraction) as usize;
        let trials = 2000;
        let mut misses = 0;
        for _ in 0..trials {
            let rp = rng.permutation(m_total);
            let cp = rng.permutation(n_total);
            // Count co-cluster members (ids < rows_in / cols_in) per block band.
            let mut row_counts = vec![0usize; m];
            for (pos, &id) in rp.iter().enumerate() {
                if id < rows_in {
                    row_counts[(pos / phi).min(m - 1)] += 1;
                }
            }
            let mut col_counts = vec![0usize; n];
            for (pos, &id) in cp.iter().enumerate() {
                if id < cols_in {
                    col_counts[(pos / psi).min(n - 1)] += 1;
                }
            }
            let detected = row_counts.iter().any(|&r| r >= p.t_m)
                && col_counts.iter().any(|&c| c >= p.t_n);
            if !detected {
                misses += 1;
            }
        }
        let empirical = misses as f64 / trials as f64;
        assert!(
            empirical <= bound + 0.02,
            "empirical miss {empirical} exceeds bound {bound}"
        );
    }
}
