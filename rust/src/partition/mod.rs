//! Large-matrix partitioning (paper §IV-B + Theorem 1).
//!
//! Three pieces:
//! * [`prob_model`] — the probabilistic detection model: tail bounds on
//!   how much of a co-cluster survives inside a block, the failure bound
//!   `P(ω_k)`, and the `T_p` solver (Eqs. 1–4 / Theorem 1).
//! * [`planner`] — enumerates block-size configurations, prices each via
//!   a cost model, and picks the cheapest one meeting `P_thresh`.
//! * [`sampler`] — materializes `T_p` random shuffled grid partitions as
//!   block jobs over global row/column indices.

pub mod planner;
pub mod prob_model;
pub mod sampler;

pub use planner::{auto_chunk_cols, plan, plan_view, PartitionPlan, PlannerConfig};
pub use prob_model::{detection_probability, failure_bound, required_samplings, CoclusterPrior};
pub use sampler::{sample_partition, sample_partition_view, BlockJob, SamplingRound};
