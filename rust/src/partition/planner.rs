//! Partition planner (paper §IV-B.2): pick `(φ, ψ, m, n, T_p)`.
//!
//! Enumerates candidate block sizes, keeps configurations whose Theorem-1
//! bound can reach `P_thresh`, prices each with an atom-cost model, and
//! returns the cheapest. Candidate sizes include the shapes for which
//! AOT-compiled PJRT artifacts exist (so the coordinator can route whole
//! grids to the accelerator path) plus power-of-two fallbacks.

use super::prob_model::{required_samplings, CoclusterPrior};

#[derive(Clone, Debug)]
pub struct PlannerConfig {
    /// Target detection probability `P_thresh` (Eq. 4).
    pub p_thresh: f64,
    /// Prior on the smallest co-cluster that must be detected.
    pub prior: CoclusterPrior,
    /// Candidate block side lengths. Empty ⇒ defaults.
    pub candidate_sizes: Vec<usize>,
    /// Worker parallelism assumed by the cost model.
    pub workers: usize,
    /// Upper bound on T_p (guards against pathological priors).
    pub max_samplings: usize,
    /// Embedding rank used by the cost model (atom SVD width).
    pub rank: usize,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        Self {
            p_thresh: 0.95,
            prior: CoclusterPrior::default(),
            candidate_sizes: vec![],
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            max_samplings: 64,
            rank: 6,
        }
    }
}

/// The chosen partition configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct PartitionPlan {
    /// Block rows (φ) — last block of each round may be smaller.
    pub phi: usize,
    /// Block cols (ψ).
    pub psi: usize,
    /// Grid rows `m = ⌈M/φ⌉`.
    pub m: usize,
    /// Grid cols `n = ⌈N/ψ⌉`.
    pub n: usize,
    /// Number of shuffled re-partitions `T_p`.
    pub t_p: usize,
    /// Detection probability certified by Theorem 1 for this plan.
    pub certified_probability: f64,
    /// Cost-model estimate (arbitrary units, comparable across plans).
    pub estimated_cost: f64,
}

impl PartitionPlan {
    /// Total block jobs the plan will schedule.
    pub fn total_blocks(&self) -> usize {
        self.m * self.n * self.t_p
    }

    /// Trivial plan: no partitioning (whole matrix, one job). Used when
    /// the matrix is already small enough for a direct atom run.
    pub fn whole(rows: usize, cols: usize) -> Self {
        Self { phi: rows, psi: cols, m: 1, n: 1, t_p: 1, certified_probability: 1.0, estimated_cost: 0.0 }
    }
}

/// Atom cost model: spectral co-clustering on a `φ×ψ` block costs
/// ~ `c · φ·ψ·rank` (subspace iteration) + `c' · (φ+ψ)·rank·k` (k-means);
/// the grid runs `m·n·T_p` of these over `workers` lanes. Per-block
/// scheduling overhead is charged too, so absurdly small blocks lose.
fn plan_cost(phi: usize, psi: usize, m: usize, n: usize, t_p: usize, cfg: &PlannerConfig) -> f64 {
    let per_block = (phi as f64) * (psi as f64) * (cfg.rank as f64)
        + 2e3 * (phi + psi) as f64 * cfg.rank as f64
        + 5e5; // fixed dispatch+gather overhead per block
    let blocks = (m * n * t_p) as f64;
    blocks * per_block / cfg.workers.max(1) as f64
}

/// Choose the cheapest feasible plan for an `M×N` matrix.
///
/// Falls back to [`PartitionPlan::whole`] when no candidate satisfies
/// the probability constraint (e.g. the prior demands fragments bigger
/// than any candidate block).
pub fn plan(rows: usize, cols: usize, cfg: &PlannerConfig) -> PartitionPlan {
    let default_sizes = [128usize, 192, 256, 384, 512, 768, 1024];
    let candidates: &[usize] = if cfg.candidate_sizes.is_empty() { &default_sizes } else { &cfg.candidate_sizes };

    let mut best: Option<PartitionPlan> = None;
    for &phi in candidates {
        if phi > rows {
            continue;
        }
        for &psi in candidates {
            if psi > cols {
                continue;
            }
            let m = rows.div_ceil(phi);
            let n = cols.div_ceil(psi);
            if m * n < 2 {
                continue; // not a partition
            }
            let Some(t_p) = required_samplings(&cfg.prior, phi, psi, m, n, cfg.p_thresh) else {
                continue;
            };
            if t_p > cfg.max_samplings {
                continue;
            }
            let cost = plan_cost(phi, psi, m, n, t_p, cfg);
            let certified = super::prob_model::detection_probability(&cfg.prior, phi, psi, m, n, t_p);
            let cand = PartitionPlan { phi, psi, m, n, t_p, certified_probability: certified, estimated_cost: cost };
            if best.as_ref().map_or(true, |b| cand.estimated_cost < b.estimated_cost) {
                best = Some(cand);
            }
        }
    }
    best.unwrap_or_else(|| PartitionPlan::whole(rows, cols))
}

/// [`plan`] for a [`crate::store::MatrixView`]: the planner only ever
/// needs the dimensions, so a store-backed matrix is planned without
/// touching a single chunk payload.
pub fn plan_view(matrix: crate::store::MatrixView<'_>, cfg: &PlannerConfig) -> PartitionPlan {
    plan(matrix.rows(), matrix.cols(), cfg)
}

/// ψ from a planner dry run on the dimensions alone — what
/// `lamc pack/ingest/repack --chunk-cols auto` sizes LAMC3 tiles to,
/// so tile boundaries align with the column spans the partitioned
/// pipeline will actually gather (a ψ-wide block then intersects one
/// column band instead of straddling several partially-read tiles).
///
/// Returns `cols` when the planner would not partition a matrix this
/// size (1×1 grid): one full-width band, i.e. the row-band layout.
pub fn auto_chunk_cols(rows: usize, cols: usize) -> usize {
    plan(rows, cols, &PlannerConfig::default()).psi
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_meets_probability_threshold() {
        let cfg = PlannerConfig::default();
        let p = plan(2000, 1500, &cfg);
        assert!(p.certified_probability >= cfg.p_thresh, "{p:?}");
        assert!(p.m >= 1 && p.n >= 1 && p.t_p >= 1);
    }

    #[test]
    fn small_matrix_returns_whole_plan() {
        // Blocks can't be larger than the matrix and a 1×1 grid is not a
        // partition, so a tiny matrix falls back to the whole plan.
        let p = plan(64, 64, &PlannerConfig::default());
        assert_eq!(p, PartitionPlan::whole(64, 64));
    }

    #[test]
    fn grid_covers_matrix() {
        let p = plan(1000, 1000, &PlannerConfig::default());
        assert!(p.m * p.phi >= 1000);
        assert!(p.n * p.psi >= 1000);
        assert!((p.m - 1) * p.phi < 1000, "no empty block rows");
    }

    #[test]
    fn stricter_threshold_needs_no_fewer_samplings() {
        let mut cfg = PlannerConfig::default();
        cfg.candidate_sizes = vec![256];
        cfg.p_thresh = 0.9;
        let loose = plan(4000, 4000, &cfg);
        cfg.p_thresh = 0.9999;
        let strict = plan(4000, 4000, &cfg);
        assert!(strict.t_p >= loose.t_p, "strict {strict:?} loose {loose:?}");
    }

    #[test]
    fn respects_candidate_restriction() {
        let cfg = PlannerConfig { candidate_sizes: vec![256], ..Default::default() };
        let p = plan(3000, 3000, &cfg);
        assert_eq!(p.phi, 256);
        assert_eq!(p.psi, 256);
    }

    #[test]
    fn cost_prefers_fewer_blocks_when_probability_equal() {
        // With a generous prior, both coarse and fine grids certify; the
        // planner should not pick pathologically tiny blocks (dispatch
        // overhead dominates).
        let cfg = PlannerConfig {
            prior: CoclusterPrior { row_fraction: 0.4, col_fraction: 0.4, t_m: 4, t_n: 4 },
            ..Default::default()
        };
        let p = plan(5000, 5000, &cfg);
        assert!(p.phi >= 256, "planner picked tiny blocks: {p:?}");
    }

    #[test]
    fn auto_chunk_cols_tracks_the_dry_run_psi() {
        // Large matrix: auto tile width is the planner's ψ.
        let p = plan(2000, 1500, &PlannerConfig::default());
        assert_eq!(auto_chunk_cols(2000, 1500), p.psi);
        assert!(auto_chunk_cols(2000, 1500) < 1500, "partitioned ⇒ narrower than the matrix");
        // Tiny matrix: whole plan ⇒ full width (row-band geometry).
        assert_eq!(auto_chunk_cols(64, 64), 64);
    }

    #[test]
    fn total_blocks_consistent() {
        let p = plan(2048, 2048, &PlannerConfig::default());
        assert_eq!(p.total_blocks(), p.m * p.n * p.t_p);
    }
}
