//! Mini property-based testing framework.
//!
//! `proptest` is not resolvable from the offline registry, so this module
//! provides the slice of it the test suites need: seeded case generation,
//! configurable case counts (`LAMC_PROP_CASES`), and failure reports that
//! include the reproducing seed.

use crate::rng::Xoshiro256;

/// Number of cases per property (env-overridable).
pub fn default_cases() -> usize {
    std::env::var("LAMC_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(64)
}

/// Run `prop` against `cases` generated inputs. `gen` maps a seeded RNG
/// to an input; `prop` returns `Err(reason)` on violation. Panics with
/// the seed + case index so failures are reproducible.
pub fn check<T: std::fmt::Debug, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Xoshiro256) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let base_seed = std::env::var("LAMC_PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xFACADEu64);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Xoshiro256::seed_from(seed);
        let input = gen(&mut rng);
        if let Err(reason) = prop(&input) {
            panic!(
                "property '{name}' falsified at case {case}/{cases}\n  seed: LAMC_PROP_SEED={base_seed} (case seed {seed:#x})\n  input: {input:?}\n  reason: {reason}"
            );
        }
    }
}

/// Convenience: assert a float is finite and within `[lo, hi]`.
pub fn in_range(x: f64, lo: f64, hi: f64, what: &str) -> Result<(), String> {
    if !x.is_finite() {
        return Err(format!("{what} is not finite: {x}"));
    }
    if x < lo || x > hi {
        return Err(format!("{what} = {x} outside [{lo}, {hi}]"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 10, |rng| rng.next_below(100), |_| {
            Ok(())
        });
        // `check` is synchronous; reaching here means all cases ran.
        count += 1;
        assert_eq!(count, 1);
    }

    #[test]
    #[should_panic(expected = "falsified")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 5, |rng| rng.next_below(10), |_| Err("nope".into()));
    }

    #[test]
    fn in_range_helper() {
        assert!(in_range(0.5, 0.0, 1.0, "x").is_ok());
        assert!(in_range(2.0, 0.0, 1.0, "x").is_err());
        assert!(in_range(f64::NAN, 0.0, 1.0, "x").is_err());
    }

    #[test]
    fn generation_is_deterministic_per_case() {
        let mut first: Vec<usize> = vec![];
        check("record", 5, |rng| rng.next_below(1000), |&x| {
            first.push(x);
            Ok(())
        });
        let mut second: Vec<usize> = vec![];
        check("record", 5, |rng| rng.next_below(1000), |&x| {
            second.push(x);
            Ok(())
        });
        assert_eq!(first, second);
    }
}
