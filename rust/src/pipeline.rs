//! End-to-end LAMC pipeline: plan → sample → schedule → merge → label.
//!
//! This is the public entry point a downstream user calls (also the core
//! of the `lamc` binary and the benches): everything from §IV of the
//! paper composed behind one `run` method.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::cocluster::{AtomCocluster, Pnmtf, SpectralCocluster};
use crate::coordinator::{run_rounds, Router, SchedulerConfig, Stats, StatsSnapshot};
use crate::merge::{extract_labels, merge_coclusters, Cocluster, MergeConfig};
use crate::partition::{plan_view, sample_partition_view, BlockJob, PartitionPlan, PlannerConfig};
#[cfg(feature = "pjrt")]
use crate::runtime::RuntimePool;
use crate::store::MatrixView;
use crate::trace::{Event, Trace};

/// Which atom algorithm runs inside each block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AtomKind {
    Scc,
    Pnmtf,
}

impl AtomKind {
    pub fn artifact_kind(&self) -> &'static str {
        match self {
            AtomKind::Scc => "scc_block",
            AtomKind::Pnmtf => "pnmtf_block",
        }
    }

    pub fn build(&self) -> Arc<dyn AtomCocluster> {
        match self {
            AtomKind::Scc => Arc::new(SpectralCocluster::default()),
            AtomKind::Pnmtf => Arc::new(Pnmtf::default()),
        }
    }
}

impl std::str::FromStr for AtomKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_lowercase().as_str() {
            "scc" => Ok(AtomKind::Scc),
            "pnmtf" => Ok(AtomKind::Pnmtf),
            other => anyhow::bail!("unknown atom '{other}' (want scc|pnmtf)"),
        }
    }
}

/// Full pipeline configuration.
#[derive(Clone)]
pub struct LamcConfig {
    /// Target number of co-clusters.
    pub k: usize,
    pub atom: AtomKind,
    /// Custom atom instance (e.g. exact-SVD SCC for the paper-faithful
    /// baseline benches). When set, overrides `atom.build()` on the
    /// native route; `atom` still selects the PJRT artifact kind.
    pub atom_override: Option<Arc<dyn AtomCocluster>>,
    pub planner: PlannerConfig,
    pub merge: MergeConfig,
    /// Worker threads (0 = auto).
    pub workers: usize,
    pub seed: u64,
    /// Job-lifecycle event sink threaded down into the scheduler
    /// (rounds, prefetch waves) and the merge stage. Advisory and
    /// disabled by default: labels are byte-identical either way.
    pub trace: Trace,
    /// Optional PJRT runtime; when set, blocks whose shape matches a
    /// compiled artifact run on the XLA route. Only available with the
    /// `pjrt` cargo feature — the default build always routes native.
    #[cfg(feature = "pjrt")]
    pub runtime: Option<Arc<RuntimePool>>,
}

impl Default for LamcConfig {
    fn default() -> Self {
        Self {
            k: 4,
            atom: AtomKind::Scc,
            atom_override: None,
            planner: PlannerConfig::default(),
            merge: MergeConfig::default(),
            workers: 0,
            seed: 0x1A3C,
            trace: Trace::default(),
            #[cfg(feature = "pjrt")]
            runtime: None,
        }
    }
}

/// Pipeline output.
#[derive(Clone, Debug)]
pub struct LamcResult {
    pub row_labels: Vec<usize>,
    pub col_labels: Vec<usize>,
    /// Number of final co-clusters.
    pub k: usize,
    /// The merged co-clusters themselves (consensus cores).
    pub coclusters: Vec<Cocluster>,
    pub plan: PartitionPlan,
    pub stats: StatsSnapshot,
    pub elapsed_s: f64,
}

/// The LAMC driver.
pub struct Lamc {
    pub config: LamcConfig,
}

impl Lamc {
    pub fn new(config: LamcConfig) -> Self {
        Self { config }
    }

    /// Convert one block's label vectors into global-id atom co-clusters.
    ///
    /// Label `t` pairs the block's rows labelled `t` with its columns
    /// labelled `t` — the coupling produced by the shared embedding
    /// k-means (SCC) / shared factor index (PNMTF).
    pub fn block_to_atoms(job: &BlockJob, result: &crate::cocluster::CoclusterResult) -> Vec<Cocluster> {
        let mut atoms = Vec::new();
        for t in 0..result.k {
            let rows: Vec<u32> = job
                .rows
                .iter()
                .zip(&result.row_labels)
                .filter_map(|(&gid, &l)| (l == t).then_some(gid as u32))
                .collect();
            let cols: Vec<u32> = job
                .cols
                .iter()
                .zip(&result.col_labels)
                .filter_map(|(&gid, &l)| (l == t).then_some(gid as u32))
                .collect();
            if !rows.is_empty() && !cols.is_empty() {
                atoms.push(Cocluster::atom(rows, cols, result.objective));
            }
        }
        atoms
    }

    /// Run the full pipeline on a matrix — in-memory (`&Matrix`, as
    /// before) or store-backed (`&MatrixRef` / `&StoreReader`): block
    /// gathers then stream row-band tiles from disk instead of copying
    /// from RAM, with byte-identical labels for equal content, seed and
    /// config (asserted by `tests/integration_store.rs`).
    pub fn run<'a>(&self, matrix: impl Into<MatrixView<'a>>) -> Result<LamcResult> {
        let matrix: MatrixView<'a> = matrix.into();
        let t0 = Instant::now();
        let cfg = &self.config;
        let (rows, cols) = (matrix.rows(), matrix.cols());
        anyhow::ensure!(rows > 0 && cols > 0, "empty matrix");

        // 1. Plan: prefer artifact shapes as block-size candidates so
        //    whole grids ride the PJRT route.
        let mut planner = cfg.planner.clone();
        #[cfg(feature = "pjrt")]
        if planner.candidate_sizes.is_empty() {
            if let Some(pool) = &cfg.runtime {
                let sizes = pool.manifest().candidate_sizes(cfg.atom.artifact_kind());
                if !sizes.is_empty() {
                    planner.candidate_sizes = sizes;
                }
            }
        }
        if planner.workers == 0 {
            planner.workers = SchedulerConfig { workers: cfg.workers, ..Default::default() }.effective_workers();
        }
        let partition_plan = plan_view(matrix, &planner);
        crate::log_info!(
            "plan: {}x{} grid of {}x{} blocks, T_p={} (P={:.4}, {} blocks total)",
            partition_plan.m, partition_plan.n, partition_plan.phi, partition_plan.psi,
            partition_plan.t_p, partition_plan.certified_probability, partition_plan.total_blocks()
        );

        // 2. Sample shuffled partitions (index permutations only — no
        //    data is read here, wherever the matrix lives).
        let mut rng = crate::coordinator::scheduler::leader_rng(cfg.seed);
        let rounds = sample_partition_view(matrix, &partition_plan, &mut rng);

        // 3. Schedule block jobs.
        let atom = cfg.atom_override.clone().unwrap_or_else(|| cfg.atom.build());
        #[cfg(feature = "pjrt")]
        let router = match &cfg.runtime {
            Some(pool) => Router::with_runtime(atom, Arc::clone(pool), cfg.atom.artifact_kind()),
            None => Router::native_only(atom),
        };
        #[cfg(not(feature = "pjrt"))]
        let router = Router::native_only(atom);
        let sched_cfg = SchedulerConfig {
            workers: cfg.workers,
            k: cfg.k,
            seed: cfg.seed,
            trace: cfg.trace.clone(),
        };
        let stats = Stats::default();
        let results = run_rounds(matrix, &rounds, &router, &sched_cfg, &stats)?;

        // 4. Hierarchical merge.
        let merge_start_us = cfg.trace.now_us();
        let t_merge = Instant::now();
        let atoms: Vec<Cocluster> = results
            .iter()
            .flat_map(|(job, res)| Self::block_to_atoms(job, res))
            .collect();
        crate::log_info!("merging {} atom co-clusters", atoms.len());
        cfg.trace.emit(Event::MergeStarted { blocks: atoms.len() as u64 });
        let merged = merge_coclusters(atoms, &cfg.merge);
        let (row_labels, col_labels, k) = extract_labels(&merged, rows, cols);
        let merge_ns = t_merge.elapsed().as_nanos() as u64;
        stats.merge_ns.store(merge_ns, std::sync::atomic::Ordering::Relaxed);
        stats.hist_merge.observe_ns(merge_ns);
        cfg.trace.add_span("merge", 0, merge_start_us, merge_ns / 1_000);
        cfg.trace.emit(Event::MergeCompleted { k: k as u64, merge_s: merge_ns as f64 / 1e9 });

        let snapshot = stats.snapshot();
        crate::log_info!("done: k={k}, {snapshot}");
        Ok(LamcResult {
            row_labels,
            col_labels,
            k,
            coclusters: merged,
            plan: partition_plan,
            stats: snapshot,
            elapsed_s: t0.elapsed().as_secs_f64(),
        })
    }

    /// Run the *baseline* (no partitioning): the atom directly on the
    /// whole matrix. Used by the Table II/III benches as SCC / PNMTF.
    ///
    /// The result is shape-compatible with [`Lamc::run`]: `coclusters`
    /// holds the atom co-clusters of the single whole-matrix job (via
    /// [`Lamc::block_to_atoms`]) and `stats` reflects the one executed
    /// block, so callers and the harness can treat both paths uniformly.
    ///
    /// Unlike the partitioned path, the baseline needs the whole matrix
    /// at once: a store-backed input is materialized into RAM first
    /// (this is exactly the memory wall the partitioned path avoids).
    pub fn run_baseline<'a>(&self, matrix: impl Into<MatrixView<'a>>) -> Result<LamcResult> {
        let matrix: MatrixView<'a> = matrix.into();
        let t0 = Instant::now();
        let cfg = &self.config;
        let atom = cfg.atom_override.clone().unwrap_or_else(|| cfg.atom.build());
        let stats = Stats::default();
        let mut rng = crate::rng::Xoshiro256::seed_from(cfg.seed);
        let whole = matrix.materialize()?;
        // Materializing a stored matrix is real I/O — surface it like
        // the partitioned path does (watermarked claim, never
        // double-counted across concurrent runs on a shared reader).
        stats.add_io(&matrix.take_io_delta());
        let t_exec = Instant::now();
        let res = atom.cocluster(&whole, cfg.k, &mut rng);
        stats.add_exec(t_exec.elapsed().as_nanos() as u64);
        stats.blocks_total.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        stats.blocks_native.fetch_add(1, std::sync::atomic::Ordering::Relaxed);

        let job = BlockJob {
            round: 0,
            grid: (0, 0),
            rows: (0..matrix.rows()).collect(),
            cols: (0..matrix.cols()).collect(),
        };
        let coclusters = Self::block_to_atoms(&job, &res);
        let plan = PartitionPlan::whole(matrix.rows(), matrix.cols());
        Ok(LamcResult {
            row_labels: res.row_labels,
            col_labels: res.col_labels,
            k: res.k,
            coclusters,
            plan,
            stats: stats.snapshot(),
            elapsed_s: t0.elapsed().as_secs_f64(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cocluster::CoclusterResult;
    use crate::data::synthetic::{planted_dense, PlantedConfig};
    use crate::metrics::score_coclustering;
    use crate::partition::prob_model::CoclusterPrior;

    fn fast_config(k: usize) -> LamcConfig {
        LamcConfig {
            k,
            planner: PlannerConfig {
                candidate_sizes: vec![128, 192, 256],
                prior: CoclusterPrior { row_fraction: 0.2, col_fraction: 0.2, t_m: 6, t_n: 6 },
                max_samplings: 8,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn block_to_atoms_maps_global_ids() {
        let job = BlockJob { round: 0, grid: (0, 0), rows: vec![10, 20, 30], cols: vec![5, 6] };
        let res = CoclusterResult { row_labels: vec![0, 1, 0], col_labels: vec![1, 0], k: 2, objective: 0.5 };
        let atoms = Lamc::block_to_atoms(&job, &res);
        assert_eq!(atoms.len(), 2);
        assert_eq!(atoms[0].rows, vec![10, 30]);
        assert_eq!(atoms[0].cols, vec![6]);
        assert_eq!(atoms[1].rows, vec![20]);
        assert_eq!(atoms[1].cols, vec![5]);
    }

    #[test]
    fn block_to_atoms_skips_row_only_clusters() {
        let job = BlockJob { round: 0, grid: (0, 0), rows: vec![1, 2], cols: vec![3] };
        let res = CoclusterResult { row_labels: vec![0, 1], col_labels: vec![0], k: 2, objective: 0.0 };
        let atoms = Lamc::block_to_atoms(&job, &res);
        assert_eq!(atoms.len(), 1, "label-1 cluster has no columns → dropped");
    }

    #[test]
    fn end_to_end_recovers_planted_structure() {
        let ds = planted_dense(&PlantedConfig {
            rows: 500,
            cols: 400,
            row_clusters: 4,
            col_clusters: 4,
            noise: 0.15,
            signal: 1.5,
            seed: 801,
            ..Default::default()
        });
        let lamc = Lamc::new(fast_config(4));
        let out = lamc.run(&ds.matrix).unwrap();
        assert!(out.plan.t_p >= 1);
        let s = score_coclustering(&ds.row_labels, &out.row_labels, &ds.col_labels, &out.col_labels);
        assert!(s.nmi() > 0.6, "nmi {} (k={})", s.nmi(), out.k);
    }

    #[test]
    fn baseline_runs_whole_matrix() {
        let ds = planted_dense(&PlantedConfig { rows: 100, cols: 80, seed: 802, ..Default::default() });
        let lamc = Lamc::new(fast_config(4));
        let out = lamc.run_baseline(&ds.matrix).unwrap();
        assert_eq!(out.row_labels.len(), 100);
        assert_eq!(out.plan, PartitionPlan::whole(100, 80));
        // Baseline results are shape-compatible with the pipeline's:
        // atom co-clusters present (with global ids) and stats counted.
        assert!(!out.coclusters.is_empty(), "baseline must derive co-clusters");
        for c in &out.coclusters {
            assert!(c.rows.iter().all(|&r| (r as usize) < 100));
            assert!(c.cols.iter().all(|&j| (j as usize) < 80));
        }
        assert_eq!(out.stats.blocks_total, 1);
        assert_eq!(out.stats.blocks_native, 1);
        assert!(out.stats.exec_s > 0.0);
    }

    #[test]
    fn atom_kind_parsing() {
        assert_eq!("scc".parse::<AtomKind>().unwrap(), AtomKind::Scc);
        assert_eq!("PNMTF".parse::<AtomKind>().unwrap(), AtomKind::Pnmtf);
        assert!("gmm".parse::<AtomKind>().is_err());
    }
}
