//! End-to-end LAMC pipeline: plan → sample → schedule → merge → label.
//!
//! This is the public entry point a downstream user calls (also the core
//! of the `lamc` binary and the benches): everything from §IV of the
//! paper composed behind one `run` method.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::cocluster::{AtomCocluster, Pnmtf, SpectralCocluster};
use crate::coordinator::{run_rounds_with, Router, RunOptions, Stats, StatsSnapshot};
use crate::merge::{extract_labels, reduce_partial_sets, Cocluster, MergeConfig};
use crate::partition::{
    plan_view, sample_partition_view, BlockJob, PartitionPlan, PlannerConfig, SamplingRound,
};
#[cfg(feature = "pjrt")]
use crate::runtime::RuntimePool;
use crate::store::MatrixView;
use crate::trace::{Event, Trace};

/// Which atom algorithm runs inside each block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AtomKind {
    Scc,
    Pnmtf,
}

impl AtomKind {
    pub fn artifact_kind(&self) -> &'static str {
        match self {
            AtomKind::Scc => "scc_block",
            AtomKind::Pnmtf => "pnmtf_block",
        }
    }

    pub fn build(&self) -> Arc<dyn AtomCocluster> {
        match self {
            AtomKind::Scc => Arc::new(SpectralCocluster::default()),
            AtomKind::Pnmtf => Arc::new(Pnmtf::default()),
        }
    }
}

impl std::str::FromStr for AtomKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_lowercase().as_str() {
            "scc" => Ok(AtomKind::Scc),
            "pnmtf" => Ok(AtomKind::Pnmtf),
            other => anyhow::bail!("unknown atom '{other}' (want scc|pnmtf)"),
        }
    }
}

/// Full pipeline configuration.
#[derive(Clone)]
pub struct LamcConfig {
    /// Target number of co-clusters.
    pub k: usize,
    pub atom: AtomKind,
    /// Custom atom instance (e.g. exact-SVD SCC for the paper-faithful
    /// baseline benches). When set, overrides `atom.build()` on the
    /// native route; `atom` still selects the PJRT artifact kind.
    pub atom_override: Option<Arc<dyn AtomCocluster>>,
    pub planner: PlannerConfig,
    pub merge: MergeConfig,
    /// Worker threads (0 = auto).
    pub workers: usize,
    pub seed: u64,
    /// Job-lifecycle event sink threaded down into the scheduler
    /// (rounds, prefetch waves) and the merge stage. Advisory and
    /// disabled by default: labels are byte-identical either way.
    pub trace: Trace,
    /// Optional PJRT runtime; when set, blocks whose shape matches a
    /// compiled artifact run on the XLA route. Only available with the
    /// `pjrt` cargo feature — the default build always routes native.
    #[cfg(feature = "pjrt")]
    pub runtime: Option<Arc<RuntimePool>>,
}

impl Default for LamcConfig {
    fn default() -> Self {
        Self {
            k: 4,
            atom: AtomKind::Scc,
            atom_override: None,
            planner: PlannerConfig::default(),
            merge: MergeConfig::default(),
            workers: 0,
            seed: 0x1A3C,
            trace: Trace::default(),
            #[cfg(feature = "pjrt")]
            runtime: None,
        }
    }
}

/// Pipeline output.
#[derive(Clone, Debug)]
pub struct LamcResult {
    pub row_labels: Vec<usize>,
    pub col_labels: Vec<usize>,
    /// Number of final co-clusters.
    pub k: usize,
    /// The merged co-clusters themselves (consensus cores).
    pub coclusters: Vec<Cocluster>,
    pub plan: PartitionPlan,
    pub stats: StatsSnapshot,
    pub elapsed_s: f64,
}

/// Per-job atom co-clusters retained from a run, enabling incremental
/// re-clustering after a store append ([`Lamc::run_incremental`]).
///
/// The basis pins the exact inputs its atoms were computed from: matrix
/// dims, content fingerprint and store append generation at run time,
/// plus — in flat (round, grid) job order — every block job and the
/// atom co-clusters it produced. An incremental run replays the plan
/// and sampling on the final data, reuses retained atoms for jobs that
/// match the basis exactly and touch no dirty rows, recomputes the
/// rest, and re-merges everything in the same flat order through
/// [`reduce_partial_sets`] — so its labels are byte-identical to a
/// from-scratch run on the same final matrix.
#[derive(Clone, Debug)]
pub struct RunBasis {
    pub rows: usize,
    pub cols: usize,
    /// Content fingerprint of the matrix the basis was computed from.
    pub fingerprint: u64,
    /// Store append generation at run time (0 for in-memory inputs and
    /// never-appended stores).
    pub generation: u64,
    /// `(job, atoms)` per block job, in flat (round, grid) order.
    pub partials: Vec<(BlockJob, Vec<Cocluster>)>,
}

/// Dirty row ranges of `matrix` relative to `basis`, or `None` when the
/// change cannot be attributed and every block must recompute:
///
/// * fingerprint and dims unchanged → nothing dirty (full reuse);
/// * column count changed or rows shrank → `None` (every block shifts);
/// * store-backed with append generations past the basis → the store's
///   per-band generation tags ([`MatrixView::dirty_rows_since`]), plus
///   any rows past the basis snapshot;
/// * otherwise (mutated in-memory matrix, replaced store file) → `None`.
fn dirty_rows_against(
    matrix: MatrixView<'_>,
    basis: &RunBasis,
    base_generation: Option<u64>,
) -> Option<Vec<(usize, usize)>> {
    if matrix.fingerprint() == basis.fingerprint
        && matrix.rows() == basis.rows
        && matrix.cols() == basis.cols
    {
        return Some(Vec::new());
    }
    if matrix.cols() != basis.cols || matrix.rows() < basis.rows {
        return None;
    }
    let gen = base_generation.unwrap_or(basis.generation);
    if matrix.generation() <= gen {
        // Different fingerprint but no newer append generation: the
        // backing data changed out from under us in a way generation
        // tags cannot localize.
        return None;
    }
    let mut ranges = matrix.dirty_rows_since(gen);
    if matrix.rows() > basis.rows {
        ranges.push((basis.rows, matrix.rows()));
    }
    Some(normalize_ranges(ranges))
}

/// Sort + coalesce half-open `[lo, hi)` ranges (adjacent ranges merge).
fn normalize_ranges(mut ranges: Vec<(usize, usize)>) -> Vec<(usize, usize)> {
    ranges.retain(|&(lo, hi)| hi > lo);
    ranges.sort_unstable();
    let mut out: Vec<(usize, usize)> = Vec::with_capacity(ranges.len());
    for (lo, hi) in ranges {
        match out.last_mut() {
            Some(last) if lo <= last.1 => last.1 = last.1.max(hi),
            _ => out.push((lo, hi)),
        }
    }
    out
}

/// Membership test against sorted, disjoint half-open ranges.
fn row_in_ranges(ranges: &[(usize, usize)], row: usize) -> bool {
    let i = ranges.partition_point(|&(_, hi)| hi <= row);
    i < ranges.len() && ranges[i].0 <= row
}

/// The LAMC driver.
pub struct Lamc {
    pub config: LamcConfig,
}

impl Lamc {
    pub fn new(config: LamcConfig) -> Self {
        Self { config }
    }

    /// Convert one block's label vectors into global-id atom co-clusters.
    ///
    /// Label `t` pairs the block's rows labelled `t` with its columns
    /// labelled `t` — the coupling produced by the shared embedding
    /// k-means (SCC) / shared factor index (PNMTF).
    pub fn block_to_atoms(job: &BlockJob, result: &crate::cocluster::CoclusterResult) -> Vec<Cocluster> {
        let mut atoms = Vec::new();
        for t in 0..result.k {
            let rows: Vec<u32> = job
                .rows
                .iter()
                .zip(&result.row_labels)
                .filter_map(|(&gid, &l)| (l == t).then_some(gid as u32))
                .collect();
            let cols: Vec<u32> = job
                .cols
                .iter()
                .zip(&result.col_labels)
                .filter_map(|(&gid, &l)| (l == t).then_some(gid as u32))
                .collect();
            if !rows.is_empty() && !cols.is_empty() {
                atoms.push(Cocluster::atom(rows, cols, result.objective));
            }
        }
        atoms
    }

    /// [`RunOptions`] seeded from this driver's config (workers, k,
    /// seed, trace) — the starting point for [`Lamc::run_with`] callers
    /// that want to override a field or two.
    pub fn options(&self) -> RunOptions {
        RunOptions::default()
            .workers(self.config.workers)
            .k(self.config.k)
            .seed(self.config.seed)
            .trace(self.config.trace.clone())
    }

    /// Run the full pipeline on a matrix — in-memory (`&Matrix`, as
    /// before) or store-backed (`&MatrixRef` / `&StoreReader`): block
    /// gathers then stream row-band tiles from disk instead of copying
    /// from RAM, with byte-identical labels for equal content, seed and
    /// config (asserted by `tests/integration_store.rs`).
    ///
    /// Positional form kept for compatibility: forwards to
    /// [`Lamc::run_with`] with [`Lamc::options`].
    pub fn run<'a>(&self, matrix: impl Into<MatrixView<'a>>) -> Result<LamcResult> {
        self.run_with(matrix, &self.options())
    }

    /// [`Lamc::run`] with named options: `opts` supplies the workers /
    /// k / seed / trace / prefetch knobs (overriding the corresponding
    /// config fields), so call sites name what they change instead of
    /// threading positional parameters.
    pub fn run_with<'a>(
        &self,
        matrix: impl Into<MatrixView<'a>>,
        opts: &RunOptions,
    ) -> Result<LamcResult> {
        Ok(self.run_inner(matrix.into(), opts, None, false)?.0)
    }

    /// [`Lamc::run_with`], additionally retaining the per-job atom sets
    /// as a [`RunBasis`] so a later [`Lamc::run_incremental`] can reuse
    /// them after the matrix grows.
    pub fn run_tracked<'a>(
        &self,
        matrix: impl Into<MatrixView<'a>>,
        opts: &RunOptions,
    ) -> Result<(LamcResult, RunBasis)> {
        let (result, basis) = self.run_inner(matrix.into(), opts, None, true)?;
        Ok((result, basis.expect("basis requested")))
    }

    /// Incremental re-clustering against a previous run's [`RunBasis`]:
    /// replays the plan and sampling on the final matrix, re-runs only
    /// the block jobs that intersect rows dirtied since the basis (or
    /// since `opts.base_generation` when set), reuses the retained
    /// atoms everywhere else, and re-merges the full flat sequence via
    /// [`reduce_partial_sets`]. Labels are byte-identical to
    /// [`Lamc::run`] on the same final matrix; the returned basis
    /// supersedes the one passed in.
    pub fn run_incremental<'a>(
        &self,
        matrix: impl Into<MatrixView<'a>>,
        opts: &RunOptions,
        basis: &RunBasis,
    ) -> Result<(LamcResult, RunBasis)> {
        let (result, next) = self.run_inner(matrix.into(), opts, Some(basis), true)?;
        Ok((result, next.expect("basis requested")))
    }

    fn run_inner(
        &self,
        matrix: MatrixView<'_>,
        opts: &RunOptions,
        basis: Option<&RunBasis>,
        want_basis: bool,
    ) -> Result<(LamcResult, Option<RunBasis>)> {
        let t0 = Instant::now();
        let cfg = &self.config;
        let (rows, cols) = (matrix.rows(), matrix.cols());
        anyhow::ensure!(rows > 0 && cols > 0, "empty matrix");

        // 1. Plan: prefer artifact shapes as block-size candidates so
        //    whole grids ride the PJRT route.
        let mut planner = cfg.planner.clone();
        #[cfg(feature = "pjrt")]
        if planner.candidate_sizes.is_empty() {
            if let Some(pool) = &cfg.runtime {
                let sizes = pool.manifest().candidate_sizes(cfg.atom.artifact_kind());
                if !sizes.is_empty() {
                    planner.candidate_sizes = sizes;
                }
            }
        }
        if planner.workers == 0 {
            planner.workers = opts.effective_workers();
        }
        let partition_plan = plan_view(matrix, &planner);
        crate::log_info!(
            "plan: {}x{} grid of {}x{} blocks, T_p={} (P={:.4}, {} blocks total)",
            partition_plan.m, partition_plan.n, partition_plan.phi, partition_plan.psi,
            partition_plan.t_p, partition_plan.certified_probability, partition_plan.total_blocks()
        );

        // 2. Sample shuffled partitions (index permutations only — no
        //    data is read here, wherever the matrix lives).
        let mut rng = crate::coordinator::scheduler::leader_rng(opts.seed);
        let rounds = sample_partition_view(matrix, &partition_plan, &mut rng);
        let flat: Vec<&BlockJob> = rounds.iter().flat_map(|r| r.jobs.iter()).collect();

        // 2b. Incremental: decide which retained atom sets still stand.
        //     A retained set is reused only when the replayed job has
        //     exactly the basis job's row/col ids and touches no dirty
        //     rows — so the merge input below cannot differ from a
        //     from-scratch run's.
        let dirty = basis.and_then(|b| dirty_rows_against(matrix, b, opts.base_generation));
        let mut atom_sets: Vec<Option<Vec<Cocluster>>> = vec![None; flat.len()];
        if let (Some(b), Some(dirty)) = (basis, dirty.as_ref()) {
            let index: HashMap<(usize, (usize, usize)), &(BlockJob, Vec<Cocluster>)> =
                b.partials.iter().map(|p| ((p.0.round, p.0.grid), p)).collect();
            let mut reused = 0usize;
            for (i, job) in flat.iter().enumerate() {
                if let Some((bjob, atoms)) = index.get(&(job.round, job.grid)).map(|p| (&p.0, &p.1))
                {
                    if bjob.rows == job.rows
                        && bjob.cols == job.cols
                        && !job.rows.iter().any(|&r| row_in_ranges(dirty, r))
                    {
                        atom_sets[i] = Some(atoms.clone());
                        reused += 1;
                    }
                }
            }
            crate::log_info!(
                "incremental: reusing {reused}/{} block jobs ({} dirty row ranges)",
                flat.len(),
                dirty.len()
            );
        }

        // 3. Schedule the jobs that still need compute (all of them on
        //    a fresh run), preserving round numbers so per-job seeds
        //    match a from-scratch run exactly.
        let mut pending: Vec<SamplingRound> = Vec::new();
        {
            let mut i = 0usize;
            for round in &rounds {
                let mut jobs = Vec::new();
                for job in &round.jobs {
                    if atom_sets[i].is_none() {
                        jobs.push(job.clone());
                    }
                    i += 1;
                }
                if !jobs.is_empty() {
                    pending.push(SamplingRound { round: round.round, jobs });
                }
            }
        }
        let atom = cfg.atom_override.clone().unwrap_or_else(|| cfg.atom.build());
        #[cfg(feature = "pjrt")]
        let router = match &cfg.runtime {
            Some(pool) => Router::with_runtime(atom, Arc::clone(pool), cfg.atom.artifact_kind()),
            None => Router::native_only(atom),
        };
        #[cfg(not(feature = "pjrt"))]
        let router = Router::native_only(atom);
        let stats = Stats::default();
        let results = run_rounds_with(matrix, &pending, &router, opts, &stats)?;

        // Slot freshly computed atoms into the flat job order (the
        // scheduler returns pending jobs in exactly that order).
        let mut computed = results.into_iter();
        for slot in atom_sets.iter_mut() {
            if slot.is_none() {
                let (job, res) = computed.next().expect("scheduler returns every pending job");
                *slot = Some(Self::block_to_atoms(&job, &res));
            }
        }
        debug_assert!(computed.next().is_none(), "scheduler returned surplus jobs");

        // 4. Hierarchical merge — always over the full flat job
        //    sequence, so incremental and from-scratch runs feed the
        //    merge byte-identical input.
        let merge_start_us = opts.trace.now_us();
        let t_merge = Instant::now();
        let partial_sets: Vec<Vec<Cocluster>> =
            atom_sets.into_iter().map(|a| a.expect("every job resolved")).collect();
        let out_basis = want_basis.then(|| RunBasis {
            rows,
            cols,
            fingerprint: matrix.fingerprint(),
            generation: matrix.generation(),
            partials: flat
                .iter()
                .zip(partial_sets.iter())
                .map(|(job, atoms)| ((**job).clone(), atoms.clone()))
                .collect(),
        });
        let n_atoms: usize = partial_sets.iter().map(|s| s.len()).sum();
        crate::log_info!("merging {n_atoms} atom co-clusters");
        opts.trace.emit(Event::MergeStarted { blocks: n_atoms as u64 });
        let merged = reduce_partial_sets(partial_sets, &cfg.merge);
        let (row_labels, col_labels, k) = extract_labels(&merged, rows, cols);
        let merge_ns = t_merge.elapsed().as_nanos() as u64;
        stats.merge_ns.store(merge_ns, std::sync::atomic::Ordering::Relaxed);
        stats.hist_merge.observe_ns(merge_ns);
        opts.trace.add_span("merge", 0, merge_start_us, merge_ns / 1_000);
        opts.trace.emit(Event::MergeCompleted { k: k as u64, merge_s: merge_ns as f64 / 1e9 });

        let snapshot = stats.snapshot();
        crate::log_info!("done: k={k}, {snapshot}");
        Ok((
            LamcResult {
                row_labels,
                col_labels,
                k,
                coclusters: merged,
                plan: partition_plan,
                stats: snapshot,
                elapsed_s: t0.elapsed().as_secs_f64(),
            },
            out_basis,
        ))
    }

    /// Run the *baseline* (no partitioning): the atom directly on the
    /// whole matrix. Used by the Table II/III benches as SCC / PNMTF.
    ///
    /// The result is shape-compatible with [`Lamc::run`]: `coclusters`
    /// holds the atom co-clusters of the single whole-matrix job (via
    /// [`Lamc::block_to_atoms`]) and `stats` reflects the one executed
    /// block, so callers and the harness can treat both paths uniformly.
    ///
    /// Unlike the partitioned path, the baseline needs the whole matrix
    /// at once: a store-backed input is materialized into RAM first
    /// (this is exactly the memory wall the partitioned path avoids).
    pub fn run_baseline<'a>(&self, matrix: impl Into<MatrixView<'a>>) -> Result<LamcResult> {
        self.run_baseline_with(matrix, &self.options())
    }

    /// [`Lamc::run_baseline`] with named options. Only `k` and `seed`
    /// participate — the baseline has no scheduler, prefetcher or
    /// incremental mode, so the other fields are ignored.
    pub fn run_baseline_with<'a>(
        &self,
        matrix: impl Into<MatrixView<'a>>,
        opts: &RunOptions,
    ) -> Result<LamcResult> {
        let matrix: MatrixView<'a> = matrix.into();
        let t0 = Instant::now();
        let cfg = &self.config;
        let atom = cfg.atom_override.clone().unwrap_or_else(|| cfg.atom.build());
        let stats = Stats::default();
        let mut rng = crate::rng::Xoshiro256::seed_from(opts.seed);
        let whole = matrix.materialize()?;
        // Materializing a stored matrix is real I/O — surface it like
        // the partitioned path does (watermarked claim, never
        // double-counted across concurrent runs on a shared reader).
        stats.add_io(&matrix.take_io_delta());
        let t_exec = Instant::now();
        let res = atom.cocluster(&whole, opts.k, &mut rng);
        stats.add_exec(t_exec.elapsed().as_nanos() as u64);
        stats.blocks_total.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        stats.blocks_native.fetch_add(1, std::sync::atomic::Ordering::Relaxed);

        let job = BlockJob {
            round: 0,
            grid: (0, 0),
            rows: (0..matrix.rows()).collect(),
            cols: (0..matrix.cols()).collect(),
        };
        let coclusters = Self::block_to_atoms(&job, &res);
        let plan = PartitionPlan::whole(matrix.rows(), matrix.cols());
        Ok(LamcResult {
            row_labels: res.row_labels,
            col_labels: res.col_labels,
            k: res.k,
            coclusters,
            plan,
            stats: stats.snapshot(),
            elapsed_s: t0.elapsed().as_secs_f64(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cocluster::CoclusterResult;
    use crate::data::synthetic::{planted_dense, PlantedConfig};
    use crate::metrics::score_coclustering;
    use crate::partition::prob_model::CoclusterPrior;

    fn fast_config(k: usize) -> LamcConfig {
        LamcConfig {
            k,
            planner: PlannerConfig {
                candidate_sizes: vec![128, 192, 256],
                prior: CoclusterPrior { row_fraction: 0.2, col_fraction: 0.2, t_m: 6, t_n: 6 },
                max_samplings: 8,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn block_to_atoms_maps_global_ids() {
        let job = BlockJob { round: 0, grid: (0, 0), rows: vec![10, 20, 30], cols: vec![5, 6] };
        let res = CoclusterResult { row_labels: vec![0, 1, 0], col_labels: vec![1, 0], k: 2, objective: 0.5 };
        let atoms = Lamc::block_to_atoms(&job, &res);
        assert_eq!(atoms.len(), 2);
        assert_eq!(atoms[0].rows, vec![10, 30]);
        assert_eq!(atoms[0].cols, vec![6]);
        assert_eq!(atoms[1].rows, vec![20]);
        assert_eq!(atoms[1].cols, vec![5]);
    }

    #[test]
    fn block_to_atoms_skips_row_only_clusters() {
        let job = BlockJob { round: 0, grid: (0, 0), rows: vec![1, 2], cols: vec![3] };
        let res = CoclusterResult { row_labels: vec![0, 1], col_labels: vec![0], k: 2, objective: 0.0 };
        let atoms = Lamc::block_to_atoms(&job, &res);
        assert_eq!(atoms.len(), 1, "label-1 cluster has no columns → dropped");
    }

    #[test]
    fn end_to_end_recovers_planted_structure() {
        let ds = planted_dense(&PlantedConfig {
            rows: 500,
            cols: 400,
            row_clusters: 4,
            col_clusters: 4,
            noise: 0.15,
            signal: 1.5,
            seed: 801,
            ..Default::default()
        });
        let lamc = Lamc::new(fast_config(4));
        let out = lamc.run(&ds.matrix).unwrap();
        assert!(out.plan.t_p >= 1);
        let s = score_coclustering(&ds.row_labels, &out.row_labels, &ds.col_labels, &out.col_labels);
        assert!(s.nmi() > 0.6, "nmi {} (k={})", s.nmi(), out.k);
    }

    #[test]
    fn baseline_runs_whole_matrix() {
        let ds = planted_dense(&PlantedConfig { rows: 100, cols: 80, seed: 802, ..Default::default() });
        let lamc = Lamc::new(fast_config(4));
        let out = lamc.run_baseline(&ds.matrix).unwrap();
        assert_eq!(out.row_labels.len(), 100);
        assert_eq!(out.plan, PartitionPlan::whole(100, 80));
        // Baseline results are shape-compatible with the pipeline's:
        // atom co-clusters present (with global ids) and stats counted.
        assert!(!out.coclusters.is_empty(), "baseline must derive co-clusters");
        for c in &out.coclusters {
            assert!(c.rows.iter().all(|&r| (r as usize) < 100));
            assert!(c.cols.iter().all(|&j| (j as usize) < 80));
        }
        assert_eq!(out.stats.blocks_total, 1);
        assert_eq!(out.stats.blocks_native, 1);
        assert!(out.stats.exec_s > 0.0);
    }

    #[test]
    fn range_helpers_normalize_and_probe() {
        assert_eq!(
            normalize_ranges(vec![(5, 7), (0, 2), (6, 9), (2, 3), (4, 4)]),
            vec![(0, 3), (5, 9)]
        );
        let r = [(0usize, 3usize), (5, 9)];
        assert!(row_in_ranges(&r, 0));
        assert!(row_in_ranges(&r, 2));
        assert!(!row_in_ranges(&r, 3));
        assert!(!row_in_ranges(&r, 4));
        assert!(row_in_ranges(&r, 5));
        assert!(row_in_ranges(&r, 8));
        assert!(!row_in_ranges(&r, 9));
        assert!(!row_in_ranges(&[], 0));
    }

    #[test]
    fn run_with_options_matches_positional_run() {
        let ds = planted_dense(&PlantedConfig { rows: 150, cols: 120, seed: 806, ..Default::default() });
        let lamc = Lamc::new(fast_config(4));
        let a = lamc.run(&ds.matrix).unwrap();
        let b = lamc.run_with(&ds.matrix, &lamc.options()).unwrap();
        assert_eq!(a.row_labels, b.row_labels);
        assert_eq!(a.col_labels, b.col_labels);
        assert_eq!(a.k, b.k);
    }

    #[test]
    fn incremental_reuses_everything_when_content_unchanged() {
        let ds = planted_dense(&PlantedConfig { rows: 150, cols: 120, seed: 807, ..Default::default() });
        let lamc = Lamc::new(fast_config(4));
        let (fresh, basis) = lamc.run_tracked(&ds.matrix, &lamc.options()).unwrap();
        assert_eq!(basis.rows, 150);
        assert_eq!(basis.cols, 120);
        assert!(!basis.partials.is_empty());
        let (incr, next) = lamc.run_incremental(&ds.matrix, &lamc.options(), &basis).unwrap();
        assert_eq!(incr.row_labels, fresh.row_labels);
        assert_eq!(incr.col_labels, fresh.col_labels);
        assert_eq!(incr.k, fresh.k);
        assert_eq!(incr.stats.blocks_total, 0, "unchanged content: every job reused");
        assert_eq!(next.fingerprint, basis.fingerprint);
        assert_eq!(next.partials.len(), basis.partials.len());
    }

    #[test]
    fn incremental_on_changed_in_memory_matrix_recomputes_and_matches_fresh() {
        // An in-memory matrix has no append generations, so any content
        // change is unattributable → full recompute, but still through
        // the incremental path, and still byte-identical to `run`.
        let a = planted_dense(&PlantedConfig { rows: 150, cols: 120, seed: 808, ..Default::default() });
        let b = planted_dense(&PlantedConfig { rows: 150, cols: 120, seed: 809, ..Default::default() });
        let lamc = Lamc::new(fast_config(4));
        let (_, basis) = lamc.run_tracked(&a.matrix, &lamc.options()).unwrap();
        let (incr, _) = lamc.run_incremental(&b.matrix, &lamc.options(), &basis).unwrap();
        let fresh = lamc.run(&b.matrix).unwrap();
        assert_eq!(incr.row_labels, fresh.row_labels);
        assert_eq!(incr.col_labels, fresh.col_labels);
        assert!(incr.stats.blocks_total > 0, "unattributable change recomputes blocks");
    }

    #[test]
    fn atom_kind_parsing() {
        assert_eq!("scc".parse::<AtomKind>().unwrap(), AtomKind::Scc);
        assert_eq!("PNMTF".parse::<AtomKind>().unwrap(), AtomKind::Pnmtf);
        assert!("gmm".parse::<AtomKind>().is_err());
    }
}
