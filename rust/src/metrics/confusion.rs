//! Contingency (confusion) table shared by NMI and ARI.

/// Dense contingency table between two label vectors over the same items.
#[derive(Clone, Debug)]
pub struct Contingency {
    /// counts[i][j] = #items with true label i and predicted label j.
    pub counts: Vec<Vec<usize>>,
    /// Row marginals (per true label).
    pub row_marginals: Vec<usize>,
    /// Column marginals (per predicted label).
    pub col_marginals: Vec<usize>,
    /// Total item count.
    pub n: usize,
}

impl Contingency {
    /// Build from label vectors. Labels may be arbitrary `usize` values;
    /// they are compacted to dense indices internally.
    pub fn from_labels(a: &[usize], b: &[usize]) -> Self {
        assert_eq!(a.len(), b.len(), "label vectors must align");
        let map_a = compact(a);
        let map_b = compact(b);
        let ka = map_a.len();
        let kb = map_b.len();
        let mut counts = vec![vec![0usize; kb]; ka];
        for (&x, &y) in a.iter().zip(b) {
            counts[map_a[&x]][map_b[&y]] += 1;
        }
        let row_marginals: Vec<usize> = counts.iter().map(|r| r.iter().sum()).collect();
        let mut col_marginals = vec![0usize; kb];
        for row in &counts {
            for (j, &c) in row.iter().enumerate() {
                col_marginals[j] += c;
            }
        }
        Self { counts, row_marginals, col_marginals, n: a.len() }
    }
}

fn compact(labels: &[usize]) -> std::collections::HashMap<usize, usize> {
    let mut map = std::collections::HashMap::new();
    for &l in labels {
        let next = map.len();
        map.entry(l).or_insert(next);
    }
    map
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn marginals_sum_to_n() {
        let a = [0, 0, 1, 2, 2, 2];
        let b = [5, 5, 9, 9, 5, 5];
        let c = Contingency::from_labels(&a, &b);
        assert_eq!(c.n, 6);
        assert_eq!(c.row_marginals.iter().sum::<usize>(), 6);
        assert_eq!(c.col_marginals.iter().sum::<usize>(), 6);
    }

    #[test]
    fn counts_match_manual() {
        let a = [0, 0, 1, 1];
        let b = [0, 1, 0, 1];
        let c = Contingency::from_labels(&a, &b);
        assert_eq!(c.counts, vec![vec![1, 1], vec![1, 1]]);
    }

    #[test]
    fn non_contiguous_labels_are_compacted() {
        let a = [100, 100, 7];
        let b = [3, 3, 3];
        let c = Contingency::from_labels(&a, &b);
        assert_eq!(c.counts.len(), 2);
        assert_eq!(c.counts[0].len(), 1);
        assert_eq!(c.row_marginals, vec![2, 1]);
    }

    #[test]
    #[should_panic(expected = "align")]
    fn mismatched_lengths_panic() {
        Contingency::from_labels(&[0, 1], &[0]);
    }
}
