//! Clustering evaluation metrics (Table III of the paper).
//!
//! Normalized Mutual Information and Adjusted Rand Index over integer
//! label vectors, plus a co-clustering aggregate that averages the row
//! and column scores (the convention used when a single number is
//! reported for a co-clustering, as in the paper's tables).

mod ari;
mod confusion;
mod nmi;

pub use ari::adjusted_rand_index;
pub use confusion::Contingency;
pub use nmi::normalized_mutual_information;

/// Joint co-clustering scores: row-wise, column-wise, and their mean.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CoclusterScores {
    pub row_nmi: f64,
    pub col_nmi: f64,
    pub row_ari: f64,
    pub col_ari: f64,
}

impl CoclusterScores {
    pub fn nmi(&self) -> f64 {
        0.5 * (self.row_nmi + self.col_nmi)
    }

    pub fn ari(&self) -> f64 {
        0.5 * (self.row_ari + self.col_ari)
    }
}

/// Score predicted row/column labels against ground truth.
pub fn score_coclustering(
    true_rows: &[usize],
    pred_rows: &[usize],
    true_cols: &[usize],
    pred_cols: &[usize],
) -> CoclusterScores {
    CoclusterScores {
        row_nmi: normalized_mutual_information(true_rows, pred_rows),
        col_nmi: normalized_mutual_information(true_cols, pred_cols),
        row_ari: adjusted_rand_index(true_rows, pred_rows),
        col_ari: adjusted_rand_index(true_cols, pred_cols),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregate_is_mean() {
        let s = CoclusterScores { row_nmi: 1.0, col_nmi: 0.0, row_ari: 0.5, col_ari: 0.5 };
        assert_eq!(s.nmi(), 0.5);
        assert_eq!(s.ari(), 0.5);
    }

    #[test]
    fn perfect_coclustering_scores_one() {
        let rows = vec![0, 0, 1, 1, 2];
        let cols = vec![1, 1, 0, 0];
        let s = score_coclustering(&rows, &rows, &cols, &cols);
        assert!((s.nmi() - 1.0).abs() < 1e-12);
        assert!((s.ari() - 1.0).abs() < 1e-12);
    }
}
