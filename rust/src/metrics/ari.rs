//! Adjusted Rand Index.

use super::confusion::Contingency;

fn comb2(n: usize) -> f64 {
    let n = n as f64;
    n * (n - 1.0) / 2.0
}

/// ARI (Hubert & Arabie 1985): Rand index corrected for chance;
/// 1 = identical partitions, ~0 = independent, can be negative.
pub fn adjusted_rand_index(a: &[usize], b: &[usize]) -> f64 {
    if a.is_empty() || a.len() == 1 {
        return 1.0;
    }
    let c = Contingency::from_labels(a, b);
    let sum_ij: f64 = c.counts.iter().flatten().map(|&nij| comb2(nij)).sum();
    let sum_a: f64 = c.row_marginals.iter().map(|&m| comb2(m)).sum();
    let sum_b: f64 = c.col_marginals.iter().map(|&m| comb2(m)).sum();
    let total = comb2(c.n);
    let expected = sum_a * sum_b / total;
    let max_index = 0.5 * (sum_a + sum_b);
    if (max_index - expected).abs() < 1e-15 {
        // Both partitions degenerate (all-singletons vs all-one-cluster
        // agreement structure): define as 1 when identical index, else 0.
        return if (sum_ij - expected).abs() < 1e-15 { 1.0 } else { 0.0 };
    }
    (sum_ij - expected) / (max_index - expected)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn identical_scores_one() {
        let a = [0, 1, 2, 0, 1, 2];
        assert!((adjusted_rand_index(&a, &a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn relabeling_invariant() {
        let a = [0, 0, 1, 1, 2, 2];
        let b = [4, 4, 9, 9, 1, 1];
        assert!((adjusted_rand_index(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn independent_near_zero() {
        let mut rng = Xoshiro256::seed_from(81);
        let n = 20_000;
        let a: Vec<usize> = (0..n).map(|_| rng.next_below(5)).collect();
        let b: Vec<usize> = (0..n).map(|_| rng.next_below(5)).collect();
        let ari = adjusted_rand_index(&a, &b);
        assert!(ari.abs() < 0.01, "ari {ari}");
    }

    #[test]
    fn known_sklearn_value() {
        // sklearn.metrics.adjusted_rand_score([0,0,1,1],[0,0,1,2]) == 0.5714285714...
        let a = [0, 0, 1, 1];
        let b = [0, 0, 1, 2];
        let ari = adjusted_rand_index(&a, &b);
        assert!((ari - 0.5714285714285714).abs() < 1e-12, "ari {ari}");
    }

    #[test]
    fn anti_correlated_can_be_negative() {
        // Checkerboard disagreement produces negative ARI.
        let a = [0, 0, 0, 1, 1, 1];
        let b = [0, 1, 2, 0, 1, 2];
        let ari = adjusted_rand_index(&a, &b);
        assert!(ari < 0.0, "ari {ari}");
    }

    #[test]
    fn symmetric() {
        let a = [0, 0, 1, 1, 2, 2, 0];
        let b = [0, 1, 1, 2, 2, 0, 0];
        assert!((adjusted_rand_index(&a, &b) - adjusted_rand_index(&b, &a)).abs() < 1e-12);
    }
}
