//! Normalized Mutual Information.

use super::confusion::Contingency;

/// NMI with arithmetic-mean normalization:
/// `NMI = 2·I(A;B) / (H(A) + H(B))`, in `[0, 1]`.
///
/// Degenerate edge case: if both labelings are single-cluster (zero
/// entropy on both sides) they are identical partitions — returns 1;
/// if exactly one side is single-cluster, returns 0 (no information).
pub fn normalized_mutual_information(a: &[usize], b: &[usize]) -> f64 {
    if a.is_empty() {
        return 1.0;
    }
    let c = Contingency::from_labels(a, b);
    let n = c.n as f64;
    let h_a = entropy(&c.row_marginals, n);
    let h_b = entropy(&c.col_marginals, n);
    if h_a == 0.0 && h_b == 0.0 {
        return 1.0;
    }
    if h_a == 0.0 || h_b == 0.0 {
        return 0.0;
    }
    let mut mi = 0.0f64;
    for (i, row) in c.counts.iter().enumerate() {
        for (j, &nij) in row.iter().enumerate() {
            if nij == 0 {
                continue;
            }
            let pij = nij as f64 / n;
            let pi = c.row_marginals[i] as f64 / n;
            let pj = c.col_marginals[j] as f64 / n;
            mi += pij * (pij / (pi * pj)).ln();
        }
    }
    // Clamp tiny negative round-off.
    (2.0 * mi / (h_a + h_b)).clamp(0.0, 1.0)
}

fn entropy(marginals: &[usize], n: f64) -> f64 {
    marginals
        .iter()
        .filter(|&&m| m > 0)
        .map(|&m| {
            let p = m as f64 / n;
            -p * p.ln()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn identical_labelings_score_one() {
        let a = [0, 1, 2, 0, 1, 2, 2];
        assert!((normalized_mutual_information(&a, &a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn relabeled_partition_scores_one() {
        let a = [0, 0, 1, 1, 2, 2];
        let b = [7, 7, 3, 3, 5, 5];
        assert!((normalized_mutual_information(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn independent_labelings_score_near_zero() {
        let mut rng = Xoshiro256::seed_from(71);
        let n = 20_000;
        let a: Vec<usize> = (0..n).map(|_| rng.next_below(4)).collect();
        let b: Vec<usize> = (0..n).map(|_| rng.next_below(4)).collect();
        let nmi = normalized_mutual_information(&a, &b);
        assert!(nmi < 0.01, "nmi {nmi}");
    }

    #[test]
    fn symmetric() {
        let a = [0, 0, 1, 1, 2, 2, 0, 1];
        let b = [0, 1, 1, 1, 2, 0, 0, 2];
        let x = normalized_mutual_information(&a, &b);
        let y = normalized_mutual_information(&b, &a);
        assert!((x - y).abs() < 1e-12);
    }

    #[test]
    fn degenerate_single_cluster_cases() {
        let single = [0usize; 5];
        let multi = [0, 1, 2, 0, 1];
        assert_eq!(normalized_mutual_information(&single, &single), 1.0);
        assert_eq!(normalized_mutual_information(&single, &multi), 0.0);
        assert_eq!(normalized_mutual_information(&multi, &single), 0.0);
    }

    #[test]
    fn refinement_scores_between_zero_and_one() {
        // b refines a: related but not identical.
        let a = [0, 0, 0, 0, 1, 1, 1, 1];
        let b = [0, 0, 1, 1, 2, 2, 3, 3];
        let nmi = normalized_mutual_information(&a, &b);
        assert!(nmi > 0.5 && nmi < 1.0, "nmi {nmi}");
    }
}
