//! Coordinator telemetry: lock-free counters, snapshotted for reports
//! (feeds the Table II time breakdowns — gather vs execute vs merge —
//! and the per-route block counts of the §V evaluation).

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

/// Fixed histogram bucket upper bounds, in seconds. Shared by every
/// latency histogram (`gather`/`exec`/`merge` round phases and queue
/// wait) so that bucket-wise aggregation across workers in the shard
/// router is exact — merging histograms with different bounds would
/// require re-binning. Spans 1 ms block gathers to 30 s stalled queue
/// waits; an implicit `+Inf` bucket terminates the series.
pub const HIST_BOUNDS: [f64; 12] =
    [0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 5.0, 30.0];

/// Bucket count including the `+Inf` overflow bucket.
pub const HIST_BUCKETS: usize = HIST_BOUNDS.len() + 1;

/// Lock-free fixed-bucket latency histogram. Buckets are stored
/// **non-cumulative** (each counts only its own bin) so concurrent
/// `observe_ns` calls touch one counter; the Prometheus cumulative
/// `le` form is produced at render time from a snapshot.
#[derive(Debug, Default)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    sum_ns: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    pub fn observe_ns(&self, ns: u64) {
        let secs = ns as f64 / 1e9;
        let idx = HIST_BOUNDS.iter().position(|&b| secs <= b).unwrap_or(HIST_BOUNDS.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut buckets = [0u64; HIST_BUCKETS];
        for (out, b) in buckets.iter_mut().zip(&self.buckets) {
            *out = b.load(Ordering::Relaxed);
        }
        HistogramSnapshot {
            buckets,
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
            count: self.count.load(Ordering::Relaxed),
        }
    }

    /// Fold a snapshot's counts into this live histogram — the service
    /// manager accumulating a pipeline run's local histograms.
    pub fn fold(&self, snap: &HistogramSnapshot) {
        for (b, n) in self.buckets.iter().zip(&snap.buckets) {
            if *n > 0 {
                b.fetch_add(*n, Ordering::Relaxed);
            }
        }
        self.sum_ns.fetch_add(snap.sum_ns, Ordering::Relaxed);
        self.count.fetch_add(snap.count, Ordering::Relaxed);
    }
}

/// Point-in-time histogram copy: the unit of wire transfer (`STATS`
/// `hist_*=` tokens) and of router-side aggregation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HistogramSnapshot {
    /// Per-bin (non-cumulative) counts; the last bin is `+Inf`.
    pub buckets: [u64; HIST_BUCKETS],
    pub sum_ns: u64,
    pub count: u64,
}

impl HistogramSnapshot {
    /// Bucket-wise sum — associative and commutative with identity
    /// `HistogramSnapshot::default()`, so the router may fold worker
    /// histograms in any order.
    pub fn merged(&self, other: &HistogramSnapshot) -> HistogramSnapshot {
        let mut buckets = [0u64; HIST_BUCKETS];
        for (out, (a, b)) in buckets.iter_mut().zip(self.buckets.iter().zip(&other.buckets)) {
            *out = a + b;
        }
        HistogramSnapshot {
            buckets,
            sum_ns: self.sum_ns + other.sum_ns,
            count: self.count + other.count,
        }
    }

    /// Cumulative counts in Prometheus `le` order; the final entry
    /// (`+Inf`) equals `count`.
    pub fn cumulative(&self) -> [u64; HIST_BUCKETS] {
        let mut out = self.buckets;
        for i in 1..HIST_BUCKETS {
            out[i] += out[i - 1];
        }
        out
    }

    /// Single-token wire form for the `STATS` kv line:
    /// `b0,..,b12,sum_ns,count` (comma-joined, no spaces).
    pub fn to_wire(&self) -> String {
        let mut parts: Vec<String> = self.buckets.iter().map(|b| b.to_string()).collect();
        parts.push(self.sum_ns.to_string());
        parts.push(self.count.to_string());
        parts.join(",")
    }

    /// Parse the [`Self::to_wire`] form.
    pub fn from_wire(token: &str) -> Result<HistogramSnapshot> {
        let fields: Vec<&str> = token.split(',').collect();
        if fields.len() != HIST_BUCKETS + 2 {
            bail!(
                "histogram token has {} fields, expected {}",
                fields.len(),
                HIST_BUCKETS + 2
            );
        }
        let parse =
            |s: &str| s.parse::<u64>().with_context(|| format!("bad histogram field '{s}'"));
        let mut buckets = [0u64; HIST_BUCKETS];
        for (out, s) in buckets.iter_mut().zip(&fields) {
            *out = parse(s)?;
        }
        Ok(HistogramSnapshot {
            buckets,
            sum_ns: parse(fields[HIST_BUCKETS])?,
            count: parse(fields[HIST_BUCKETS + 1])?,
        })
    }
}

/// Live counters shared across workers.
#[derive(Debug, Default)]
pub struct Stats {
    pub blocks_total: AtomicU64,
    pub blocks_native: AtomicU64,
    pub blocks_pjrt: AtomicU64,
    /// PJRT failures that fell back to the native route.
    pub pjrt_fallbacks: AtomicU64,
    pub gather_ns: AtomicU64,
    pub exec_ns: AtomicU64,
    pub merge_ns: AtomicU64,
    /// Service result-cache hits (whole jobs answered without running
    /// the pipeline). Only the long-lived service path bumps these; a
    /// one-shot batch run reports zeros.
    pub cache_hits: AtomicU64,
    /// Service result-cache misses (jobs that ran the pipeline).
    pub cache_misses: AtomicU64,
    /// Store-reader I/O folded in per run (all zero for in-memory
    /// inputs): chunks decoded off disk, payload bytes read, and
    /// decoded-chunk cache hits — the counters that used to be visible
    /// only on the `StoreReader` itself, invisible through the service.
    pub store_chunks_read: AtomicU64,
    pub store_bytes_read: AtomicU64,
    /// Uncompressed bytes produced by chunk decodes. Equals
    /// `store_bytes_read` on uncompressed stores; the gap is the I/O
    /// the payload codec saved.
    pub store_bytes_decoded: AtomicU64,
    pub store_cache_hits: AtomicU64,
    /// Background-prefetch telemetry (see `store::prefetch`): chunks
    /// pulled ahead of the compute wave, chunk requests answered by a
    /// prefetched chunk, and prefetched bytes evicted unconsumed.
    pub prefetch_issued: AtomicU64,
    pub prefetch_hits: AtomicU64,
    pub prefetch_wasted_bytes: AtomicU64,
    /// Latency distributions behind the `_seconds_total` sums above:
    /// per-round (single-node) or per-block (worker) phase durations,
    /// plus queue wait (submit → a runner picks the job up). The shard
    /// router does not observe into these locally — it aggregates its
    /// workers' histograms bucket-wise at scrape time.
    pub hist_gather: Histogram,
    pub hist_exec: Histogram,
    pub hist_merge: Histogram,
    pub hist_queue_wait: Histogram,
}

impl Stats {
    pub fn add_gather(&self, ns: u64) {
        self.gather_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn add_exec(&self, ns: u64) {
        self.exec_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Fold a store-reader counter delta (`IoCounters::delta_since`)
    /// into this run's telemetry.
    pub fn add_io(&self, io: &crate::store::IoCounters) {
        self.store_chunks_read.fetch_add(io.chunks_read, Ordering::Relaxed);
        self.store_bytes_read.fetch_add(io.bytes_read, Ordering::Relaxed);
        self.store_bytes_decoded.fetch_add(io.bytes_decoded, Ordering::Relaxed);
        self.store_cache_hits.fetch_add(io.cache_hits, Ordering::Relaxed);
        self.prefetch_issued.fetch_add(io.prefetch_issued, Ordering::Relaxed);
        self.prefetch_hits.fetch_add(io.prefetch_hits, Ordering::Relaxed);
        self.prefetch_wasted_bytes.fetch_add(io.prefetch_wasted_bytes, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            blocks_total: self.blocks_total.load(Ordering::Relaxed),
            blocks_native: self.blocks_native.load(Ordering::Relaxed),
            blocks_pjrt: self.blocks_pjrt.load(Ordering::Relaxed),
            pjrt_fallbacks: self.pjrt_fallbacks.load(Ordering::Relaxed),
            gather_s: self.gather_ns.load(Ordering::Relaxed) as f64 / 1e9,
            exec_s: self.exec_ns.load(Ordering::Relaxed) as f64 / 1e9,
            merge_s: self.merge_ns.load(Ordering::Relaxed) as f64 / 1e9,
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            store_chunks_read: self.store_chunks_read.load(Ordering::Relaxed),
            store_bytes_read: self.store_bytes_read.load(Ordering::Relaxed),
            store_bytes_decoded: self.store_bytes_decoded.load(Ordering::Relaxed),
            store_cache_hits: self.store_cache_hits.load(Ordering::Relaxed),
            prefetch_issued: self.prefetch_issued.load(Ordering::Relaxed),
            prefetch_hits: self.prefetch_hits.load(Ordering::Relaxed),
            prefetch_wasted_bytes: self.prefetch_wasted_bytes.load(Ordering::Relaxed),
            hist_gather: self.hist_gather.snapshot(),
            hist_exec: self.hist_exec.snapshot(),
            hist_merge: self.hist_merge.snapshot(),
            hist_queue_wait: self.hist_queue_wait.snapshot(),
        }
    }
}

/// Point-in-time copy for reporting.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StatsSnapshot {
    pub blocks_total: u64,
    pub blocks_native: u64,
    pub blocks_pjrt: u64,
    pub pjrt_fallbacks: u64,
    pub gather_s: f64,
    pub exec_s: f64,
    pub merge_s: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub store_chunks_read: u64,
    pub store_bytes_read: u64,
    pub store_bytes_decoded: u64,
    pub store_cache_hits: u64,
    pub prefetch_issued: u64,
    pub prefetch_hits: u64,
    pub prefetch_wasted_bytes: u64,
    pub hist_gather: HistogramSnapshot,
    pub hist_exec: HistogramSnapshot,
    pub hist_merge: HistogramSnapshot,
    pub hist_queue_wait: HistogramSnapshot,
}

impl StatsSnapshot {
    /// Field-wise sum of two snapshots — the shard router's aggregation
    /// over per-worker `STATS` replies.
    ///
    /// PR 5's per-run I/O watermarking (`take_io_delta` folded into one
    /// process's `Stats`) assumes a single process; in a routed run
    /// each worker holds its own counters and the per-run claim only
    /// holds for the *sum*. Time accumulators sum too: the result reads
    /// as total worker-seconds, not elapsed wall clock.
    pub fn merged(&self, other: &StatsSnapshot) -> StatsSnapshot {
        StatsSnapshot {
            blocks_total: self.blocks_total + other.blocks_total,
            blocks_native: self.blocks_native + other.blocks_native,
            blocks_pjrt: self.blocks_pjrt + other.blocks_pjrt,
            pjrt_fallbacks: self.pjrt_fallbacks + other.pjrt_fallbacks,
            gather_s: self.gather_s + other.gather_s,
            exec_s: self.exec_s + other.exec_s,
            merge_s: self.merge_s + other.merge_s,
            cache_hits: self.cache_hits + other.cache_hits,
            cache_misses: self.cache_misses + other.cache_misses,
            store_chunks_read: self.store_chunks_read + other.store_chunks_read,
            store_bytes_read: self.store_bytes_read + other.store_bytes_read,
            store_bytes_decoded: self.store_bytes_decoded + other.store_bytes_decoded,
            store_cache_hits: self.store_cache_hits + other.store_cache_hits,
            prefetch_issued: self.prefetch_issued + other.prefetch_issued,
            prefetch_hits: self.prefetch_hits + other.prefetch_hits,
            prefetch_wasted_bytes: self.prefetch_wasted_bytes + other.prefetch_wasted_bytes,
            hist_gather: self.hist_gather.merged(&other.hist_gather),
            hist_exec: self.hist_exec.merged(&other.hist_exec),
            hist_merge: self.hist_merge.merged(&other.hist_merge),
            hist_queue_wait: self.hist_queue_wait.merged(&other.hist_queue_wait),
        }
    }
}

impl std::fmt::Display for StatsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "blocks={} (native={}, pjrt={}, fallbacks={}) gather={:.3}s exec={:.3}s merge={:.3}s cache={}h/{}m",
            self.blocks_total, self.blocks_native, self.blocks_pjrt, self.pjrt_fallbacks,
            self.gather_s, self.exec_s, self.merge_s, self.cache_hits, self.cache_misses
        )?;
        // Store-backed runs only: keep in-memory output unchanged. A
        // fully cache-served run still counts as store-backed.
        if self.store_chunks_read > 0 || self.store_cache_hits > 0 || self.prefetch_issued > 0 {
            write!(
                f,
                " io={}c/{}B({}h) prefetch={}i/{}h/{}wB",
                self.store_chunks_read,
                self.store_bytes_read,
                self.store_cache_hits,
                self.prefetch_issued,
                self.prefetch_hits,
                self.prefetch_wasted_bytes
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_counters() {
        let s = Stats::default();
        s.blocks_total.fetch_add(3, Ordering::Relaxed);
        s.blocks_native.fetch_add(2, Ordering::Relaxed);
        s.blocks_pjrt.fetch_add(1, Ordering::Relaxed);
        s.add_gather(1_500_000_000);
        let snap = s.snapshot();
        assert_eq!(snap.blocks_total, 3);
        assert_eq!(snap.blocks_native, 2);
        assert_eq!(snap.blocks_pjrt, 1);
        assert!((snap.gather_s - 1.5).abs() < 1e-9);
    }

    #[test]
    fn display_is_informative() {
        let snap = Stats::default().snapshot();
        let text = format!("{snap}");
        assert!(text.contains("blocks=0"));
        assert!(text.contains("cache=0h/0m"));
    }

    #[test]
    fn io_counters_fold_into_snapshot() {
        let s = Stats::default();
        s.add_io(&crate::store::IoCounters {
            chunks_read: 4,
            bytes_read: 1024,
            bytes_decoded: 2048,
            cache_hits: 7,
            prefetch_issued: 3,
            prefetch_hits: 2,
            prefetch_wasted_bytes: 256,
        });
        let snap = s.snapshot();
        assert_eq!(snap.store_chunks_read, 4);
        assert_eq!(snap.store_bytes_read, 1024);
        assert_eq!(snap.store_bytes_decoded, 2048);
        assert_eq!(snap.store_cache_hits, 7);
        assert_eq!(snap.prefetch_issued, 3);
        assert_eq!(snap.prefetch_hits, 2);
        assert_eq!(snap.prefetch_wasted_bytes, 256);
        let text = format!("{snap}");
        assert!(text.contains("io=4c/1024B(7h)"), "{text}");
        assert!(text.contains("prefetch=3i/2h/256wB"), "{text}");
    }

    #[test]
    fn merged_sums_every_field() {
        // Distinct primes per field on both sides: a field that is
        // dropped, duplicated, or cross-wired in `merged` breaks an
        // equality below.
        let a = StatsSnapshot {
            blocks_total: 2,
            blocks_native: 3,
            blocks_pjrt: 5,
            pjrt_fallbacks: 7,
            gather_s: 0.25,
            exec_s: 0.5,
            merge_s: 0.125,
            cache_hits: 11,
            cache_misses: 13,
            store_chunks_read: 17,
            store_bytes_read: 19,
            store_bytes_decoded: 97,
            store_cache_hits: 23,
            prefetch_issued: 29,
            prefetch_hits: 31,
            prefetch_wasted_bytes: 37,
            ..StatsSnapshot::default()
        };
        let b = StatsSnapshot {
            blocks_total: 41,
            blocks_native: 43,
            blocks_pjrt: 47,
            pjrt_fallbacks: 53,
            gather_s: 1.0,
            exec_s: 2.0,
            merge_s: 4.0,
            cache_hits: 59,
            cache_misses: 61,
            store_chunks_read: 67,
            store_bytes_read: 71,
            store_bytes_decoded: 101,
            store_cache_hits: 73,
            prefetch_issued: 79,
            prefetch_hits: 83,
            prefetch_wasted_bytes: 89,
            ..StatsSnapshot::default()
        };
        let m = a.merged(&b);
        assert_eq!(m.blocks_total, 43);
        assert_eq!(m.blocks_native, 46);
        assert_eq!(m.blocks_pjrt, 52);
        assert_eq!(m.pjrt_fallbacks, 60);
        assert!((m.gather_s - 1.25).abs() < 1e-12);
        assert!((m.exec_s - 2.5).abs() < 1e-12);
        assert!((m.merge_s - 4.125).abs() < 1e-12);
        assert_eq!(m.cache_hits, 70);
        assert_eq!(m.cache_misses, 74);
        assert_eq!(m.store_chunks_read, 84);
        assert_eq!(m.store_bytes_read, 90);
        assert_eq!(m.store_bytes_decoded, 198);
        assert_eq!(m.store_cache_hits, 96);
        assert_eq!(m.prefetch_issued, 108);
        assert_eq!(m.prefetch_hits, 114);
        assert_eq!(m.prefetch_wasted_bytes, 126);
        // Identity on the zero snapshot.
        assert_eq!(a.merged(&StatsSnapshot::default()), a);
    }

    #[test]
    fn cache_counters_snapshot() {
        let s = Stats::default();
        s.cache_hits.fetch_add(2, Ordering::Relaxed);
        s.cache_misses.fetch_add(1, Ordering::Relaxed);
        let snap = s.snapshot();
        assert_eq!(snap.cache_hits, 2);
        assert_eq!(snap.cache_misses, 1);
    }

    #[test]
    fn histogram_buckets_observations_by_bound() {
        let h = Histogram::default();
        h.observe_ns(500_000); // 0.5 ms -> first bucket (le 0.001)
        h.observe_ns(1_000_000); // exactly 1 ms -> still le 0.001 (inclusive)
        h.observe_ns(30_000_000); // 30 ms -> le 0.05
        h.observe_ns(120_000_000_000); // 120 s -> +Inf
        let snap = h.snapshot();
        assert_eq!(snap.buckets[0], 2);
        assert_eq!(snap.buckets[5], 1);
        assert_eq!(snap.buckets[HIST_BUCKETS - 1], 1);
        assert_eq!(snap.count, 4);
        assert_eq!(snap.sum_ns, 500_000 + 1_000_000 + 30_000_000 + 120_000_000_000);
        let cum = snap.cumulative();
        assert_eq!(cum[HIST_BUCKETS - 1], snap.count, "+Inf bucket equals count");
        assert!(cum.windows(2).all(|w| w[0] <= w[1]), "cumulative is monotone");
    }

    #[test]
    fn histogram_wire_round_trips() {
        let h = Histogram::default();
        h.observe_ns(3_000_000);
        h.observe_ns(700_000_000);
        let snap = h.snapshot();
        let token = snap.to_wire();
        assert!(!token.contains(' '), "wire form must be a single token");
        assert_eq!(HistogramSnapshot::from_wire(&token).unwrap(), snap);
        assert!(HistogramSnapshot::from_wire("1,2,3").is_err(), "wrong arity");
        assert!(HistogramSnapshot::from_wire(&token.replace('0', "x")).is_err());
    }

    #[test]
    fn histogram_merge_is_associative_and_commutative() {
        let mk = |seed: u64| {
            let h = Histogram::default();
            // Spread observations across bins deterministically.
            for i in 0..seed {
                h.observe_ns((i + 1) * seed * 900_000);
            }
            h.snapshot()
        };
        let (a, b, c) = (mk(3), mk(7), mk(13));
        assert_eq!(a.merged(&b).merged(&c), a.merged(&b.merged(&c)), "associative");
        assert_eq!(a.merged(&b), b.merged(&a), "commutative");
        assert_eq!(a.merged(&HistogramSnapshot::default()), a, "identity");
        let all = a.merged(&b).merged(&c);
        assert_eq!(all.count, a.count + b.count + c.count);
        assert_eq!(all.cumulative()[HIST_BUCKETS - 1], all.count);
    }

    #[test]
    fn snapshot_merge_folds_histograms() {
        let s1 = Stats::default();
        s1.hist_gather.observe_ns(2_000_000);
        let s2 = Stats::default();
        s2.hist_gather.observe_ns(400_000_000);
        s2.hist_queue_wait.observe_ns(1_000);
        let m = s1.snapshot().merged(&s2.snapshot());
        assert_eq!(m.hist_gather.count, 2);
        assert_eq!(m.hist_queue_wait.count, 1);
        assert_eq!(m.hist_exec.count, 0);
    }
}
