//! Coordinator telemetry: lock-free counters, snapshotted for reports
//! (feeds the Table II time breakdowns — gather vs execute vs merge —
//! and the per-route block counts of the §V evaluation).

use std::sync::atomic::{AtomicU64, Ordering};

/// Live counters shared across workers.
#[derive(Debug, Default)]
pub struct Stats {
    pub blocks_total: AtomicU64,
    pub blocks_native: AtomicU64,
    pub blocks_pjrt: AtomicU64,
    /// PJRT failures that fell back to the native route.
    pub pjrt_fallbacks: AtomicU64,
    pub gather_ns: AtomicU64,
    pub exec_ns: AtomicU64,
    pub merge_ns: AtomicU64,
    /// Service result-cache hits (whole jobs answered without running
    /// the pipeline). Only the long-lived service path bumps these; a
    /// one-shot batch run reports zeros.
    pub cache_hits: AtomicU64,
    /// Service result-cache misses (jobs that ran the pipeline).
    pub cache_misses: AtomicU64,
    /// Store-reader I/O folded in per run (all zero for in-memory
    /// inputs): chunks decoded off disk, payload bytes read, and
    /// decoded-chunk cache hits — the counters that used to be visible
    /// only on the `StoreReader` itself, invisible through the service.
    pub store_chunks_read: AtomicU64,
    pub store_bytes_read: AtomicU64,
    pub store_cache_hits: AtomicU64,
    /// Background-prefetch telemetry (see `store::prefetch`): chunks
    /// pulled ahead of the compute wave, chunk requests answered by a
    /// prefetched chunk, and prefetched bytes evicted unconsumed.
    pub prefetch_issued: AtomicU64,
    pub prefetch_hits: AtomicU64,
    pub prefetch_wasted_bytes: AtomicU64,
}

impl Stats {
    pub fn add_gather(&self, ns: u64) {
        self.gather_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn add_exec(&self, ns: u64) {
        self.exec_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Fold a store-reader counter delta (`IoCounters::delta_since`)
    /// into this run's telemetry.
    pub fn add_io(&self, io: &crate::store::IoCounters) {
        self.store_chunks_read.fetch_add(io.chunks_read, Ordering::Relaxed);
        self.store_bytes_read.fetch_add(io.bytes_read, Ordering::Relaxed);
        self.store_cache_hits.fetch_add(io.cache_hits, Ordering::Relaxed);
        self.prefetch_issued.fetch_add(io.prefetch_issued, Ordering::Relaxed);
        self.prefetch_hits.fetch_add(io.prefetch_hits, Ordering::Relaxed);
        self.prefetch_wasted_bytes.fetch_add(io.prefetch_wasted_bytes, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            blocks_total: self.blocks_total.load(Ordering::Relaxed),
            blocks_native: self.blocks_native.load(Ordering::Relaxed),
            blocks_pjrt: self.blocks_pjrt.load(Ordering::Relaxed),
            pjrt_fallbacks: self.pjrt_fallbacks.load(Ordering::Relaxed),
            gather_s: self.gather_ns.load(Ordering::Relaxed) as f64 / 1e9,
            exec_s: self.exec_ns.load(Ordering::Relaxed) as f64 / 1e9,
            merge_s: self.merge_ns.load(Ordering::Relaxed) as f64 / 1e9,
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            store_chunks_read: self.store_chunks_read.load(Ordering::Relaxed),
            store_bytes_read: self.store_bytes_read.load(Ordering::Relaxed),
            store_cache_hits: self.store_cache_hits.load(Ordering::Relaxed),
            prefetch_issued: self.prefetch_issued.load(Ordering::Relaxed),
            prefetch_hits: self.prefetch_hits.load(Ordering::Relaxed),
            prefetch_wasted_bytes: self.prefetch_wasted_bytes.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy for reporting.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StatsSnapshot {
    pub blocks_total: u64,
    pub blocks_native: u64,
    pub blocks_pjrt: u64,
    pub pjrt_fallbacks: u64,
    pub gather_s: f64,
    pub exec_s: f64,
    pub merge_s: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub store_chunks_read: u64,
    pub store_bytes_read: u64,
    pub store_cache_hits: u64,
    pub prefetch_issued: u64,
    pub prefetch_hits: u64,
    pub prefetch_wasted_bytes: u64,
}

impl std::fmt::Display for StatsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "blocks={} (native={}, pjrt={}, fallbacks={}) gather={:.3}s exec={:.3}s merge={:.3}s cache={}h/{}m",
            self.blocks_total, self.blocks_native, self.blocks_pjrt, self.pjrt_fallbacks,
            self.gather_s, self.exec_s, self.merge_s, self.cache_hits, self.cache_misses
        )?;
        // Store-backed runs only: keep in-memory output unchanged. A
        // fully cache-served run still counts as store-backed.
        if self.store_chunks_read > 0 || self.store_cache_hits > 0 || self.prefetch_issued > 0 {
            write!(
                f,
                " io={}c/{}B({}h) prefetch={}i/{}h/{}wB",
                self.store_chunks_read,
                self.store_bytes_read,
                self.store_cache_hits,
                self.prefetch_issued,
                self.prefetch_hits,
                self.prefetch_wasted_bytes
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_counters() {
        let s = Stats::default();
        s.blocks_total.fetch_add(3, Ordering::Relaxed);
        s.blocks_native.fetch_add(2, Ordering::Relaxed);
        s.blocks_pjrt.fetch_add(1, Ordering::Relaxed);
        s.add_gather(1_500_000_000);
        let snap = s.snapshot();
        assert_eq!(snap.blocks_total, 3);
        assert_eq!(snap.blocks_native, 2);
        assert_eq!(snap.blocks_pjrt, 1);
        assert!((snap.gather_s - 1.5).abs() < 1e-9);
    }

    #[test]
    fn display_is_informative() {
        let snap = Stats::default().snapshot();
        let text = format!("{snap}");
        assert!(text.contains("blocks=0"));
        assert!(text.contains("cache=0h/0m"));
    }

    #[test]
    fn io_counters_fold_into_snapshot() {
        let s = Stats::default();
        s.add_io(&crate::store::IoCounters {
            chunks_read: 4,
            bytes_read: 1024,
            cache_hits: 7,
            prefetch_issued: 3,
            prefetch_hits: 2,
            prefetch_wasted_bytes: 256,
        });
        let snap = s.snapshot();
        assert_eq!(snap.store_chunks_read, 4);
        assert_eq!(snap.store_bytes_read, 1024);
        assert_eq!(snap.store_cache_hits, 7);
        assert_eq!(snap.prefetch_issued, 3);
        assert_eq!(snap.prefetch_hits, 2);
        assert_eq!(snap.prefetch_wasted_bytes, 256);
        let text = format!("{snap}");
        assert!(text.contains("io=4c/1024B(7h)"), "{text}");
        assert!(text.contains("prefetch=3i/2h/256wB"), "{text}");
    }

    #[test]
    fn cache_counters_snapshot() {
        let s = Stats::default();
        s.cache_hits.fetch_add(2, Ordering::Relaxed);
        s.cache_misses.fetch_add(1, Ordering::Relaxed);
        let snap = s.snapshot();
        assert_eq!(snap.cache_hits, 2);
        assert_eq!(snap.cache_misses, 1);
    }
}
