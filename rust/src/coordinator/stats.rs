//! Coordinator telemetry: lock-free counters, snapshotted for reports
//! (feeds the Table II time breakdowns — gather vs execute vs merge —
//! and the per-route block counts of the §V evaluation).

use std::sync::atomic::{AtomicU64, Ordering};

/// Live counters shared across workers.
#[derive(Debug, Default)]
pub struct Stats {
    pub blocks_total: AtomicU64,
    pub blocks_native: AtomicU64,
    pub blocks_pjrt: AtomicU64,
    /// PJRT failures that fell back to the native route.
    pub pjrt_fallbacks: AtomicU64,
    pub gather_ns: AtomicU64,
    pub exec_ns: AtomicU64,
    pub merge_ns: AtomicU64,
    /// Service result-cache hits (whole jobs answered without running
    /// the pipeline). Only the long-lived service path bumps these; a
    /// one-shot batch run reports zeros.
    pub cache_hits: AtomicU64,
    /// Service result-cache misses (jobs that ran the pipeline).
    pub cache_misses: AtomicU64,
}

impl Stats {
    pub fn add_gather(&self, ns: u64) {
        self.gather_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn add_exec(&self, ns: u64) {
        self.exec_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            blocks_total: self.blocks_total.load(Ordering::Relaxed),
            blocks_native: self.blocks_native.load(Ordering::Relaxed),
            blocks_pjrt: self.blocks_pjrt.load(Ordering::Relaxed),
            pjrt_fallbacks: self.pjrt_fallbacks.load(Ordering::Relaxed),
            gather_s: self.gather_ns.load(Ordering::Relaxed) as f64 / 1e9,
            exec_s: self.exec_ns.load(Ordering::Relaxed) as f64 / 1e9,
            merge_s: self.merge_ns.load(Ordering::Relaxed) as f64 / 1e9,
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy for reporting.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StatsSnapshot {
    pub blocks_total: u64,
    pub blocks_native: u64,
    pub blocks_pjrt: u64,
    pub pjrt_fallbacks: u64,
    pub gather_s: f64,
    pub exec_s: f64,
    pub merge_s: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
}

impl std::fmt::Display for StatsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "blocks={} (native={}, pjrt={}, fallbacks={}) gather={:.3}s exec={:.3}s merge={:.3}s cache={}h/{}m",
            self.blocks_total, self.blocks_native, self.blocks_pjrt, self.pjrt_fallbacks,
            self.gather_s, self.exec_s, self.merge_s, self.cache_hits, self.cache_misses
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_counters() {
        let s = Stats::default();
        s.blocks_total.fetch_add(3, Ordering::Relaxed);
        s.blocks_native.fetch_add(2, Ordering::Relaxed);
        s.blocks_pjrt.fetch_add(1, Ordering::Relaxed);
        s.add_gather(1_500_000_000);
        let snap = s.snapshot();
        assert_eq!(snap.blocks_total, 3);
        assert_eq!(snap.blocks_native, 2);
        assert_eq!(snap.blocks_pjrt, 1);
        assert!((snap.gather_s - 1.5).abs() < 1e-9);
    }

    #[test]
    fn display_is_informative() {
        let snap = Stats::default().snapshot();
        let text = format!("{snap}");
        assert!(text.contains("blocks=0"));
        assert!(text.contains("cache=0h/0m"));
    }

    #[test]
    fn cache_counters_snapshot() {
        let s = Stats::default();
        s.cache_hits.fetch_add(2, Ordering::Relaxed);
        s.cache_misses.fetch_add(1, Ordering::Relaxed);
        let snap = s.snapshot();
        assert_eq!(snap.cache_hits, 2);
        assert_eq!(snap.cache_misses, 1);
    }
}
