//! Worker-pool scheduler for block jobs (paper §IV-C: the leader/worker
//! structure that co-clusters the partitioned submatrices in parallel).
//!
//! Pull-based load balancing: workers claim the next job index from an
//! atomic counter, gather the block from the (shared, read-only) input
//! matrix, execute via the [`Router`], and push the result into a
//! channel the leader drains. Pull scheduling gives natural backpressure
//! — a worker never holds more than one gathered block — and the atomic
//! counter keeps long-tail blocks from serializing behind a static
//! round-robin assignment.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;

use crate::matrix::Matrix;
use crate::partition::{BlockJob, SamplingRound};
use crate::rng::{SplitMix64, Xoshiro256};

use super::router::Router;
use super::stats::Stats;

#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Worker threads. 0 = available parallelism.
    pub workers: usize,
    /// Co-cluster count requested from each block.
    pub k: usize,
    /// Base seed; per-job seeds are derived deterministically from it
    /// and the job's (round, grid) coordinates, so results do not depend
    /// on worker interleaving.
    pub seed: u64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self { workers: 0, k: 4, seed: 0x5EED }
    }
}

impl SchedulerConfig {
    pub fn effective_workers(&self) -> usize {
        if self.workers > 0 {
            self.workers
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        }
    }
}

/// Deterministic per-job seed: independent of scheduling order.
pub fn job_seed(base: u64, job: &BlockJob) -> u64 {
    let mut sm = SplitMix64::new(
        base ^ ((job.round as u64) << 40) ^ ((job.grid.0 as u64) << 20) ^ job.grid.1 as u64,
    );
    sm.next_u64()
}

/// Execute every job of every round; returns `(job, result)` pairs in a
/// deterministic order (sorted by (round, grid)) regardless of worker
/// interleaving.
pub fn run_rounds(
    matrix: &Matrix,
    rounds: &[SamplingRound],
    router: &Router,
    cfg: &SchedulerConfig,
    stats: &Stats,
) -> Result<Vec<(BlockJob, crate::cocluster::CoclusterResult)>> {
    let jobs: Vec<&BlockJob> = rounds.iter().flat_map(|r| r.jobs.iter()).collect();
    if jobs.is_empty() {
        return Ok(vec![]);
    }
    let workers = cfg.effective_workers().min(jobs.len());
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let jobs = &jobs;
            let next = &next;
            scope.spawn(move || {
                loop {
                    let idx = next.fetch_add(1, Ordering::Relaxed);
                    if idx >= jobs.len() {
                        break;
                    }
                    let job = jobs[idx];
                    let t0 = Instant::now();
                    let block = matrix.gather_block(&job.rows, &job.cols);
                    stats.add_gather(t0.elapsed().as_nanos() as u64);

                    let seed = job_seed(cfg.seed, job);
                    let t1 = Instant::now();
                    let result = router.execute(&block, cfg.k, seed, stats);
                    stats.add_exec(t1.elapsed().as_nanos() as u64);
                    stats.blocks_total.fetch_add(1, Ordering::Relaxed);

                    // Leader never drops the receiver while workers run.
                    let _ = tx.send((idx, result));
                }
            });
        }
        drop(tx);

        let mut out: Vec<Option<(BlockJob, crate::cocluster::CoclusterResult)>> = (0..jobs.len()).map(|_| None).collect();
        let mut first_err: Option<anyhow::Error> = None;
        for (idx, result) in rx {
            match result {
                Ok(r) => out[idx] = Some((jobs[idx].clone(), r)),
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        Ok(out.into_iter().flatten().collect())
    })
}

/// Convenience used by tests/examples: run one atom over the whole
/// matrix through the same scheduler machinery.
pub fn run_whole(
    matrix: &Matrix,
    router: &Router,
    cfg: &SchedulerConfig,
    stats: &Stats,
) -> Result<crate::cocluster::CoclusterResult> {
    let job = BlockJob {
        round: 0,
        grid: (0, 0),
        rows: (0..matrix.rows()).collect(),
        cols: (0..matrix.cols()).collect(),
    };
    let round = SamplingRound { round: 0, jobs: vec![job] };
    let mut results = run_rounds(matrix, &[round], router, cfg, stats)?;
    anyhow::ensure!(results.len() == 1, "whole-matrix job vanished");
    Ok(results.pop().unwrap().1)
}

/// Derive an RNG for leader-side stochastic stages (sampling) that is
/// decoupled from per-job seeds.
pub fn leader_rng(seed: u64) -> Xoshiro256 {
    Xoshiro256::seed_from(seed ^ 0x1EADE12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cocluster::SpectralCocluster;
    use crate::data::synthetic::{planted_dense, PlantedConfig};
    use crate::partition::{sample_partition, PartitionPlan};
    use std::sync::Arc;

    fn setup() -> (Matrix, Vec<SamplingRound>) {
        let ds = planted_dense(&PlantedConfig { rows: 120, cols: 100, seed: 701, ..Default::default() });
        let plan = PartitionPlan { phi: 60, psi: 50, m: 2, n: 2, t_p: 2, certified_probability: 1.0, estimated_cost: 0.0 };
        let mut rng = Xoshiro256::seed_from(17);
        let rounds = sample_partition(120, 100, &plan, &mut rng);
        (ds.matrix, rounds)
    }

    #[test]
    fn all_jobs_complete() {
        let (matrix, rounds) = setup();
        let router = Router::native_only(Arc::new(SpectralCocluster::default()));
        let stats = Stats::default();
        let out = run_rounds(&matrix, &rounds, &router, &SchedulerConfig::default(), &stats).unwrap();
        assert_eq!(out.len(), 8);
        assert_eq!(stats.snapshot().blocks_total, 8);
        for (job, result) in &out {
            result.validate(job.rows.len(), job.cols.len()).unwrap();
        }
    }

    #[test]
    fn results_deterministic_across_worker_counts() {
        let (matrix, rounds) = setup();
        let router = Router::native_only(Arc::new(SpectralCocluster::default()));
        let one = run_rounds(&matrix, &rounds, &router, &SchedulerConfig { workers: 1, ..Default::default() }, &Stats::default()).unwrap();
        let many = run_rounds(&matrix, &rounds, &router, &SchedulerConfig { workers: 7, ..Default::default() }, &Stats::default()).unwrap();
        assert_eq!(one.len(), many.len());
        for ((ja, ra), (jb, rb)) in one.iter().zip(&many) {
            assert_eq!(ja.grid, jb.grid);
            assert_eq!(ja.round, jb.round);
            assert_eq!(ra, rb, "job {:?} differs across worker counts", ja.grid);
        }
    }

    #[test]
    fn job_seed_depends_on_coordinates_not_order() {
        let a = BlockJob { round: 0, grid: (0, 1), rows: vec![], cols: vec![] };
        let b = BlockJob { round: 0, grid: (1, 0), rows: vec![], cols: vec![] };
        let c = BlockJob { round: 1, grid: (0, 1), rows: vec![], cols: vec![] };
        assert_ne!(job_seed(5, &a), job_seed(5, &b));
        assert_ne!(job_seed(5, &a), job_seed(5, &c));
        assert_eq!(job_seed(5, &a), job_seed(5, &a.clone()));
    }

    #[test]
    fn empty_rounds_ok() {
        let (matrix, _) = setup();
        let router = Router::native_only(Arc::new(SpectralCocluster::default()));
        let out = run_rounds(&matrix, &[], &router, &SchedulerConfig::default(), &Stats::default()).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn run_whole_matches_direct_atom() {
        let (matrix, _) = setup();
        let router = Router::native_only(Arc::new(SpectralCocluster::default()));
        let cfg = SchedulerConfig { k: 4, seed: 99, ..Default::default() };
        let via_sched = run_whole(&matrix, &router, &cfg, &Stats::default()).unwrap();
        via_sched.validate(matrix.rows(), matrix.cols()).unwrap();
    }
}
