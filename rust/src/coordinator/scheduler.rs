//! Worker-pool scheduler for block jobs (paper §IV-C: the leader/worker
//! structure that co-clusters the partitioned submatrices in parallel).
//!
//! Pull-based load balancing: workers claim the next job index from an
//! atomic counter, gather the block from the (shared, read-only) input
//! matrix, execute via the [`Router`], and write the result into a
//! slot the leader collects. Pull scheduling gives natural backpressure
//! — a worker never holds more than one gathered block — and the atomic
//! counter keeps long-tail blocks from serializing behind a static
//! round-robin assignment.
//!
//! Execution happens on the persistent process-wide
//! [`crate::service::WorkerPool`] (plus the calling thread): threads are
//! spawned once and amortized across every `run_rounds` call, instead of
//! the per-call `thread::scope` workers earlier versions used. Results
//! stay deterministic and (round, grid)-ordered regardless of pool size
//! or interleaving with concurrent service requests.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use crate::partition::{BlockJob, SamplingRound};
use crate::rng::{SplitMix64, Xoshiro256};
use crate::service::WorkerPool;
use crate::store::{IoCounters, MatrixView};
use crate::trace::{Event, Trace};

use super::router::Router;
use super::stats::Stats;

#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Concurrency cap: how many claim loops (calling thread + shared
    /// pool threads) may process this call's jobs. 0 = available
    /// parallelism. Never affects results, only speed.
    pub workers: usize,
    /// Co-cluster count requested from each block.
    pub k: usize,
    /// Base seed; per-job seeds are derived deterministically from it
    /// and the job's (round, grid) coordinates, so results do not depend
    /// on worker interleaving.
    pub seed: u64,
    /// Job-lifecycle event sink ([`Event::RoundStarted`],
    /// [`Event::RoundCompleted`], [`Event::PrefetchWave`]). Advisory:
    /// disabled by default and never affects results, only visibility.
    pub trace: Trace,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self { workers: 0, k: 4, seed: 0x5EED, trace: Trace::default() }
    }
}

impl SchedulerConfig {
    pub fn effective_workers(&self) -> usize {
        if self.workers > 0 {
            self.workers
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        }
    }
}

/// Named options for [`run_rounds_with`] and the pipeline entry points
/// ([`crate::pipeline::Lamc::run_with`] and friends), replacing the
/// accreted positional knobs of the older signatures. Every field has
/// the same default the positional forms used; construct with
/// `RunOptions::default()` and chain the builder methods:
///
/// ```
/// use lamc::coordinator::RunOptions;
/// let opts = RunOptions::default().workers(4).seed(7).prefetch(false);
/// assert_eq!(opts.workers, 4);
/// assert!(opts.base_generation.is_none());
/// ```
#[derive(Clone, Debug)]
pub struct RunOptions {
    /// Concurrency cap (0 = available parallelism). Never affects
    /// results, only speed.
    pub workers: usize,
    /// Co-cluster count requested from each block.
    pub k: usize,
    /// Base seed for leader sampling and per-job seeds.
    pub seed: u64,
    /// Job-lifecycle event sink. Advisory: results never depend on it.
    pub trace: Trace,
    /// Let a store-backed matrix overlap next-round chunk I/O with the
    /// current round's compute (default on). Advisory: turning it off
    /// only changes wall-clock, never results.
    pub prefetch: bool,
    /// Incremental mode (pipeline only): the store append generation a
    /// previous run's [`crate::pipeline::RunBasis`] was computed
    /// against. `None` means "the basis's own recorded generation".
    /// The raw scheduler ignores this field.
    pub base_generation: Option<u64>,
}

impl Default for RunOptions {
    fn default() -> Self {
        Self {
            workers: 0,
            k: 4,
            seed: 0x5EED,
            trace: Trace::default(),
            prefetch: true,
            base_generation: None,
        }
    }
}

impl RunOptions {
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    pub fn k(mut self, k: usize) -> Self {
        self.k = k;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn trace(mut self, trace: Trace) -> Self {
        self.trace = trace;
        self
    }

    pub fn prefetch(mut self, on: bool) -> Self {
        self.prefetch = on;
        self
    }

    pub fn base_generation(mut self, generation: u64) -> Self {
        self.base_generation = Some(generation);
        self
    }

    pub fn effective_workers(&self) -> usize {
        if self.workers > 0 {
            self.workers
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        }
    }
}

impl From<&SchedulerConfig> for RunOptions {
    fn from(cfg: &SchedulerConfig) -> Self {
        Self {
            workers: cfg.workers,
            k: cfg.k,
            seed: cfg.seed,
            trace: cfg.trace.clone(),
            prefetch: true,
            base_generation: None,
        }
    }
}

/// Deterministic per-job seed: independent of scheduling order.
pub fn job_seed(base: u64, job: &BlockJob) -> u64 {
    let mut sm = SplitMix64::new(
        base ^ ((job.round as u64) << 40) ^ ((job.grid.0 as u64) << 20) ^ job.grid.1 as u64,
    );
    sm.next_u64()
}

/// Execute every job of every round; returns `(job, result)` pairs in a
/// deterministic order (sorted by (round, grid)) regardless of worker
/// interleaving.
///
/// `matrix` is anything that views as a [`MatrixView`]: a borrowed
/// in-memory [`crate::matrix::Matrix`] (gathers copy from RAM, as
/// before) or a store-backed handle (each worker's gather reads only the
/// row bands its block touches, so peak memory is workers × block size
/// rather than matrix size).
///
/// Rounds execute as successive waves, and the leader hands the store's
/// background prefetcher round `r+1`'s chunk plan *before dispatching
/// round `r`* — the whole job grid is known up front, so disk I/O for
/// the next round overlaps the current round's co-clustering instead of
/// serializing in front of it (a no-op for in-memory matrices). Results
/// never depend on prefetch; only wall-clock does. The store I/O the
/// call generated (chunks/bytes read, cache and prefetch hits) is
/// folded into `stats` as a per-run delta.
pub fn run_rounds<'a>(
    matrix: impl Into<MatrixView<'a>>,
    rounds: &[SamplingRound],
    router: &Router,
    cfg: &SchedulerConfig,
    stats: &Stats,
) -> Result<Vec<(BlockJob, crate::cocluster::CoclusterResult)>> {
    // Deprecated positional form, kept so existing call sites compile
    // unchanged: forwards to [`run_rounds_with`]. New code should build
    // a [`RunOptions`] instead.
    run_rounds_with(matrix, rounds, router, &RunOptions::from(cfg), stats)
}

/// [`run_rounds`] with named options: same execution, but the knobs
/// (workers, k, seed, trace, prefetch) arrive as a [`RunOptions`]
/// builder instead of a positional config.
pub fn run_rounds_with<'a>(
    matrix: impl Into<MatrixView<'a>>,
    rounds: &[SamplingRound],
    router: &Router,
    opts: &RunOptions,
    stats: &Stats,
) -> Result<Vec<(BlockJob, crate::cocluster::CoclusterResult)>> {
    let matrix: MatrixView<'a> = matrix.into();
    let jobs: Vec<&BlockJob> = rounds.iter().flat_map(|r| r.jobs.iter()).collect();
    if jobs.is_empty() {
        return Ok(vec![]);
    }
    let slots: Mutex<Vec<Option<Result<crate::cocluster::CoclusterResult>>>> =
        Mutex::new((0..jobs.len()).map(|_| None).collect());

    let trace = &opts.trace;
    // Per-round (gather_ns, exec_ns) accumulation feeding the
    // `RoundCompleted` events; `round_of` maps a flat job index back to
    // its round.
    let round_of: Vec<usize> = rounds
        .iter()
        .enumerate()
        .flat_map(|(r, round)| std::iter::repeat_n(r, round.jobs.len()))
        .collect();
    let round_ns: Vec<(AtomicU64, AtomicU64)> =
        (0..rounds.len()).map(|_| (AtomicU64::new(0), AtomicU64::new(0))).collect();
    // Span tree: one `round-<r>` span per round (reserved up front so
    // worker threads can parent their per-block spans under it before
    // the round's duration is known), parented under the caller's span
    // (the manager's job span through `Trace::child_of`; the tree root
    // for a bare pipeline run).
    let round_span: Vec<u64> = (0..rounds.len()).map(|_| trace.reserve_span()).collect();

    // One claim-loop body shared by both dispatch shapes below.
    let run_one = |idx: usize| {
        let job = jobs[idx];
        let gather_start_us = trace.now_us();
        let t0 = Instant::now();
        let block = matrix.gather_block(&job.rows, &job.cols);
        let gather_ns = t0.elapsed().as_nanos() as u64;
        stats.add_gather(gather_ns);
        round_ns[round_of[idx]].0.fetch_add(gather_ns, Ordering::Relaxed);
        trace.record_span(
            trace.reserve_span(),
            round_span[round_of[idx]],
            "gather",
            0,
            gather_start_us,
            gather_ns / 1_000,
        );

        let result = match block {
            Ok(block) => {
                let seed = job_seed(opts.seed, job);
                let exec_start_us = trace.now_us();
                let t1 = Instant::now();
                let result = router.execute(&block, opts.k, seed, stats);
                let exec_ns = t1.elapsed().as_nanos() as u64;
                stats.add_exec(exec_ns);
                round_ns[round_of[idx]].1.fetch_add(exec_ns, Ordering::Relaxed);
                stats.blocks_total.fetch_add(1, Ordering::Relaxed);
                trace.record_span(
                    trace.reserve_span(),
                    round_span[round_of[idx]],
                    "exec",
                    0,
                    exec_start_us,
                    exec_ns / 1_000,
                );
                result
            }
            // Gather failure (store I/O or checksum): the job carries
            // the error to the leader, which reports the first one.
            Err(e) => Err(e),
        };

        // Per-job lock is negligible next to gather + co-clustering.
        slots.lock().unwrap()[idx] = Some(result);
    };

    // Per-round latency distributions, observed once per round when its
    // accumulators are final (the wire/export unit is the round here;
    // shard workers observe per block).
    let observe_round_hists = |r: usize| {
        stats.hist_gather.observe_ns(round_ns[r].0.load(Ordering::Relaxed));
        stats.hist_exec.observe_ns(round_ns[r].1.load(Ordering::Relaxed));
    };

    let round_completed = |r: usize, io: &IoCounters| Event::RoundCompleted {
        round: r as u64,
        jobs: rounds[r].jobs.len() as u64,
        gather_s: round_ns[r].0.load(Ordering::Relaxed) as f64 / 1e9,
        exec_s: round_ns[r].1.load(Ordering::Relaxed) as f64 / 1e9,
        io_chunks: io.chunks_read,
        io_bytes: io.bytes_read,
        io_cache_hits: io.cache_hits,
        prefetch_issued: io.prefetch_issued,
        prefetch_hits: io.prefetch_hits,
        prefetch_wasted_bytes: io.prefetch_wasted_bytes,
    };

    if !opts.prefetch || !matrix.prefetch_enabled() {
        // Nothing to prefetch (in-memory matrix, a reader with prefetch
        // disabled, or prefetch opted out): keep the flat single-wave dispatch —
        // workers stay busy across round boundaries instead of idling
        // behind each round's straggler.
        let flat_start_us = trace.now_us();
        for (r, round) in rounds.iter().enumerate() {
            if !round.jobs.is_empty() {
                trace.emit(Event::RoundStarted { round: r as u64, jobs: round.jobs.len() as u64 });
            }
        }
        let concurrency = opts.effective_workers().min(jobs.len());
        WorkerPool::global().run_jobs(concurrency, jobs.len(), &run_one);
        // Fold the store I/O this reader accumulated (watermarked claim,
        // so concurrent runs sharing the reader never double-count).
        // Flat dispatch has no per-round I/O boundary: the run's whole
        // delta rides on the last round's event.
        let io = matrix.take_io_delta();
        stats.add_io(&io);
        // Likewise no per-round wall-clock boundary: every round span
        // covers the single wave the rounds actually ran in.
        let flat_dur_us = trace.now_us().saturating_sub(flat_start_us);
        let last = rounds.iter().rposition(|round| !round.jobs.is_empty());
        for (r, round) in rounds.iter().enumerate() {
            if round.jobs.is_empty() {
                continue;
            }
            observe_round_hists(r);
            trace.record_span(
                round_span[r],
                trace.parent(),
                &format!("round-{r}"),
                0,
                flat_start_us,
                flat_dur_us,
            );
            if trace.enabled() {
                let io_r = if Some(r) == last { io } else { IoCounters::default() };
                trace.emit(round_completed(r, &io_r));
            }
        }
    } else {
        // Store-backed with a live prefetcher: rounds execute as waves
        // so the leader can hand the prefetcher round r+1's plan before
        // dispatching round r. Warm round 0 while its own wave spins up
        // (intra-round overlap)…
        matrix.prefetch_plan(&rounds[..1]);
        trace.emit(Event::PrefetchWave { round: 0 });
        let mut base = 0usize;
        for (r, round) in rounds.iter().enumerate() {
            // …then stream round r+1's chunks while round r computes.
            if r + 1 < rounds.len() {
                matrix.prefetch_plan(&rounds[r + 1..r + 2]);
                trace.emit(Event::PrefetchWave { round: (r + 1) as u64 });
            }
            if round.jobs.is_empty() {
                continue;
            }
            trace.emit(Event::RoundStarted { round: r as u64, jobs: round.jobs.len() as u64 });
            let round_start_us = trace.now_us();
            let concurrency = opts.effective_workers().min(round.jobs.len());
            let offset = base;
            WorkerPool::global().run_jobs(concurrency, round.jobs.len(), |i| run_one(offset + i));
            base += round.jobs.len();
            observe_round_hists(r);
            trace.record_span(
                round_span[r],
                trace.parent(),
                &format!("round-{r}"),
                0,
                round_start_us,
                trace.now_us().saturating_sub(round_start_us),
            );
            if trace.enabled() {
                // Claim this wave's I/O delta so the event carries it;
                // the claim still reaches `stats` right here, and the
                // final claim below scoops any late prefetch residue.
                let io = matrix.take_io_delta();
                stats.add_io(&io);
                trace.emit(round_completed(r, &io));
            }
        }
        // Fold the store I/O this reader accumulated (watermarked claim,
        // so concurrent runs sharing the reader never double-count).
        stats.add_io(&matrix.take_io_delta());
    }

    let mut out = Vec::with_capacity(jobs.len());
    let mut first_err: Option<anyhow::Error> = None;
    for (idx, slot) in slots.into_inner().unwrap().into_iter().enumerate() {
        match slot.expect("run_jobs processed every index") {
            Ok(r) => out.push((jobs[idx].clone(), r)),
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    Ok(out)
}

/// Convenience used by tests/examples: run one atom over the whole
/// matrix through the same scheduler machinery.
pub fn run_whole<'a>(
    matrix: impl Into<MatrixView<'a>>,
    router: &Router,
    cfg: &SchedulerConfig,
    stats: &Stats,
) -> Result<crate::cocluster::CoclusterResult> {
    let matrix: MatrixView<'a> = matrix.into();
    let job = BlockJob {
        round: 0,
        grid: (0, 0),
        rows: (0..matrix.rows()).collect(),
        cols: (0..matrix.cols()).collect(),
    };
    let round = SamplingRound { round: 0, jobs: vec![job] };
    let mut results = run_rounds(matrix, &[round], router, cfg, stats)?;
    anyhow::ensure!(results.len() == 1, "whole-matrix job vanished");
    Ok(results.pop().unwrap().1)
}

/// Derive an RNG for leader-side stochastic stages (sampling) that is
/// decoupled from per-job seeds.
pub fn leader_rng(seed: u64) -> Xoshiro256 {
    Xoshiro256::seed_from(seed ^ 0x1EADE12)
}

/// One contiguous row band of a sharded matrix, as owned by shard
/// workers (`[row_lo, row_hi)` in parent coordinates).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BandSpan {
    pub row_lo: usize,
    pub row_hi: usize,
}

/// Index of the band containing `row`, for `bands` sorted by `row_lo`
/// and contiguous. `None` when `row` falls outside every band.
pub fn band_of(bands: &[BandSpan], row: usize) -> Option<usize> {
    let i = bands.partition_point(|b| b.row_hi <= row);
    (i < bands.len() && bands[i].row_lo <= row && row < bands[i].row_hi).then_some(i)
}

/// Where one block job's rows live: which bands it touches, at which
/// positions in the job's row list, and which band dominates.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JobBandPlan {
    /// Index into the flat (rounds → jobs) job sequence.
    pub job: usize,
    /// Band contributing the most rows (ties → lowest band index): the
    /// router executes the job on an owner of this band so the largest
    /// row share is gathered locally instead of shipped.
    pub primary: usize,
    /// Per touched band (ascending band index): the positions into the
    /// job's row list whose rows live in that band.
    pub per_band: Vec<(usize, Vec<usize>)>,
}

/// Key each job of the flat job sequence by band ownership — the shard
/// router's round plan. Sampling is dims-only, so the router derives
/// `jobs` from the manifest alone and this plan never sees matrix data.
/// Errors if any sampled row falls outside every band (a topology that
/// does not cover the matrix).
pub fn plan_jobs_by_band(jobs: &[&BlockJob], bands: &[BandSpan]) -> Result<Vec<JobBandPlan>> {
    let mut out = Vec::with_capacity(jobs.len());
    for (j, job) in jobs.iter().enumerate() {
        let mut per_band: Vec<(usize, Vec<usize>)> = Vec::new();
        for (pos, &row) in job.rows.iter().enumerate() {
            let band = band_of(bands, row).ok_or_else(|| {
                anyhow::anyhow!(
                    "row {row} of job {j} (round {}, grid {:?}) is outside every shard band",
                    job.round,
                    job.grid
                )
            })?;
            match per_band.binary_search_by_key(&band, |&(b, _)| b) {
                Ok(i) => per_band[i].1.push(pos),
                Err(i) => per_band.insert(i, (band, vec![pos])),
            }
        }
        // Largest row share wins; per_band is in ascending band order,
        // so a strict `>` makes ties fall to the lowest band index.
        let mut primary = 0;
        let mut best = 0;
        for (band, positions) in &per_band {
            if positions.len() > best {
                best = positions.len();
                primary = *band;
            }
        }
        out.push(JobBandPlan { job: j, primary, per_band });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cocluster::SpectralCocluster;
    use crate::data::synthetic::{planted_dense, PlantedConfig};
    use crate::matrix::Matrix;
    use crate::partition::{sample_partition, PartitionPlan};
    use std::sync::Arc;

    fn setup() -> (Matrix, Vec<SamplingRound>) {
        let ds = planted_dense(&PlantedConfig { rows: 120, cols: 100, seed: 701, ..Default::default() });
        let plan = PartitionPlan { phi: 60, psi: 50, m: 2, n: 2, t_p: 2, certified_probability: 1.0, estimated_cost: 0.0 };
        let mut rng = Xoshiro256::seed_from(17);
        let rounds = sample_partition(120, 100, &plan, &mut rng);
        (ds.matrix, rounds)
    }

    #[test]
    fn all_jobs_complete() {
        let (matrix, rounds) = setup();
        let router = Router::native_only(Arc::new(SpectralCocluster::default()));
        let stats = Stats::default();
        let out = run_rounds(&matrix, &rounds, &router, &SchedulerConfig::default(), &stats).unwrap();
        assert_eq!(out.len(), 8);
        assert_eq!(stats.snapshot().blocks_total, 8);
        for (job, result) in &out {
            result.validate(job.rows.len(), job.cols.len()).unwrap();
        }
    }

    #[test]
    fn results_deterministic_across_worker_counts() {
        let (matrix, rounds) = setup();
        let router = Router::native_only(Arc::new(SpectralCocluster::default()));
        let one = run_rounds(&matrix, &rounds, &router, &SchedulerConfig { workers: 1, ..Default::default() }, &Stats::default()).unwrap();
        let many = run_rounds(&matrix, &rounds, &router, &SchedulerConfig { workers: 7, ..Default::default() }, &Stats::default()).unwrap();
        assert_eq!(one.len(), many.len());
        for ((ja, ra), (jb, rb)) in one.iter().zip(&many) {
            assert_eq!(ja.grid, jb.grid);
            assert_eq!(ja.round, jb.round);
            assert_eq!(ra, rb, "job {:?} differs across worker counts", ja.grid);
        }
    }

    #[test]
    fn run_options_form_matches_positional_form() {
        let (matrix, rounds) = setup();
        let router = Router::native_only(Arc::new(SpectralCocluster::default()));
        let old = run_rounds(&matrix, &rounds, &router, &SchedulerConfig::default(), &Stats::default()).unwrap();
        let new = run_rounds_with(&matrix, &rounds, &router, &RunOptions::default(), &Stats::default()).unwrap();
        assert_eq!(old, new, "RunOptions defaults mirror SchedulerConfig defaults");
        let opts = RunOptions::default().prefetch(false);
        let flat = run_rounds_with(&matrix, &rounds, &router, &opts, &Stats::default()).unwrap();
        assert_eq!(old, flat, "prefetch is advisory: results identical");
    }

    #[test]
    fn job_seed_depends_on_coordinates_not_order() {
        let a = BlockJob { round: 0, grid: (0, 1), rows: vec![], cols: vec![] };
        let b = BlockJob { round: 0, grid: (1, 0), rows: vec![], cols: vec![] };
        let c = BlockJob { round: 1, grid: (0, 1), rows: vec![], cols: vec![] };
        assert_ne!(job_seed(5, &a), job_seed(5, &b));
        assert_ne!(job_seed(5, &a), job_seed(5, &c));
        assert_eq!(job_seed(5, &a), job_seed(5, &a.clone()));
    }

    #[test]
    fn concurrent_calls_share_the_pool() {
        // Two run_rounds calls racing on the global pool must not cross
        // results or lose jobs (the service issues exactly this pattern).
        let (matrix, rounds) = setup();
        let matrix = Arc::new(matrix);
        let rounds = Arc::new(rounds);
        let mut handles = Vec::new();
        for seed in [3u64, 4] {
            let matrix = Arc::clone(&matrix);
            let rounds = Arc::clone(&rounds);
            handles.push(std::thread::spawn(move || {
                let router = Router::native_only(Arc::new(SpectralCocluster::default()));
                let cfg = SchedulerConfig { seed, ..Default::default() };
                run_rounds(matrix.as_ref(), &rounds, &router, &cfg, &Stats::default()).unwrap()
            }));
        }
        let a = handles.remove(0).join().unwrap();
        let b = handles.remove(0).join().unwrap();
        assert_eq!(a.len(), 8);
        assert_eq!(b.len(), 8);
        // Different base seeds → different per-job seeds → (generically)
        // different results; identical job coordinates in both.
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.0.grid, y.0.grid);
            assert_eq!(x.0.round, y.0.round);
        }
    }

    #[test]
    fn band_lookup_and_job_plans() {
        let bands = [
            BandSpan { row_lo: 0, row_hi: 4 },
            BandSpan { row_lo: 4, row_hi: 10 },
            BandSpan { row_lo: 10, row_hi: 12 },
        ];
        assert_eq!(band_of(&bands, 0), Some(0));
        assert_eq!(band_of(&bands, 3), Some(0));
        assert_eq!(band_of(&bands, 4), Some(1));
        assert_eq!(band_of(&bands, 11), Some(2));
        assert_eq!(band_of(&bands, 12), None);

        // Sampled (permuted) rows: positions must index the job's row
        // list, not the parent rows.
        let job = BlockJob { round: 0, grid: (0, 0), rows: vec![11, 2, 5, 7, 0], cols: vec![0] };
        let plans = plan_jobs_by_band(&[&job], &bands).unwrap();
        assert_eq!(plans.len(), 1);
        let plan = &plans[0];
        assert_eq!(plan.job, 0);
        assert_eq!(plan.primary, 0, "bands 0 and 1 hold two rows each; ties go low");
        assert_eq!(
            plan.per_band,
            vec![(0, vec![1, 4]), (1, vec![2, 3]), (2, vec![0])],
            "ascending band order, positions into the job row list"
        );

        let tie = BlockJob { round: 0, grid: (0, 1), rows: vec![5, 1, 11, 7], cols: vec![0] };
        let plans = plan_jobs_by_band(&[&tie], &bands).unwrap();
        assert_eq!(plans[0].primary, 1, "two rows in band 1 beat one row each elsewhere");
        let even = BlockJob { round: 0, grid: (1, 0), rows: vec![5, 1], cols: vec![0] };
        let plans = plan_jobs_by_band(&[&even, &tie], &bands).unwrap();
        assert_eq!(plans[0].primary, 0, "1-vs-1 tie resolves to the lowest band index");
        assert_eq!(plans[1].job, 1);

        // A row outside every band is a typed error, not a silent skip.
        let stray = BlockJob { round: 2, grid: (0, 0), rows: vec![2, 99], cols: vec![0] };
        let err = plan_jobs_by_band(&[&stray], &bands).unwrap_err().to_string();
        assert!(err.contains("outside every shard band"), "{err}");
    }

    #[test]
    fn trace_emits_round_events_without_changing_results() {
        let (matrix, rounds) = setup();
        let router = Router::native_only(Arc::new(SpectralCocluster::default()));
        let journal = Arc::new(crate::trace::Journal::new(64));
        let cfg = SchedulerConfig {
            trace: Trace::to_journal(Arc::clone(&journal)),
            ..Default::default()
        };
        let traced = run_rounds(&matrix, &rounds, &router, &cfg, &Stats::default()).unwrap();
        let plain =
            run_rounds(&matrix, &rounds, &router, &SchedulerConfig::default(), &Stats::default())
                .unwrap();
        assert_eq!(traced, plain, "tracing is advisory: results identical");

        let recs = journal.events_after(None, usize::MAX);
        let starts = recs
            .iter()
            .filter(|r| matches!(r.event, Event::RoundStarted { .. }))
            .count();
        let completed: Vec<(u64, u64)> = recs
            .iter()
            .filter_map(|r| match r.event {
                Event::RoundCompleted { round, jobs, .. } => Some((round, jobs)),
                _ => None,
            })
            .collect();
        assert_eq!(starts, completed.len(), "every started round completes");
        assert_eq!(completed.len(), 2, "setup() samples two rounds");
        assert_eq!(completed.iter().map(|&(_, j)| j).sum::<u64>(), 8, "all 8 jobs accounted");
    }

    #[test]
    fn empty_rounds_ok() {
        let (matrix, _) = setup();
        let router = Router::native_only(Arc::new(SpectralCocluster::default()));
        let out = run_rounds(&matrix, &[], &router, &SchedulerConfig::default(), &Stats::default()).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn run_whole_matches_direct_atom() {
        let (matrix, _) = setup();
        let router = Router::native_only(Arc::new(SpectralCocluster::default()));
        let cfg = SchedulerConfig { k: 4, seed: 99, ..Default::default() };
        let via_sched = run_whole(&matrix, &router, &cfg, &Stats::default()).unwrap();
        via_sched.validate(matrix.rows(), matrix.cols()).unwrap();
    }
}
