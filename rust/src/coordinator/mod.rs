//! Layer-3 coordinator: routing, scheduling, and telemetry.
//!
//! The coordinator owns the request path of LAMC (paper §IV-C: parallel
//! co-clustering of the partitioned submatrices): it takes the partition
//! planner's block jobs, routes each to an execution backend (the PJRT
//! artifact route when a compiled shape fits and the `pjrt` feature is
//! enabled, the native Rust route otherwise), fans them out over a
//! worker pool with pull-based load balancing, and collects per-route
//! telemetry.

pub mod router;
pub mod scheduler;
pub mod stats;

#[cfg(feature = "pjrt")]
pub use router::PjrtExecutor;
pub use router::{BlockExecutor, NativeExecutor, Route, Router};
pub use scheduler::{
    band_of, plan_jobs_by_band, run_rounds, run_rounds_with, BandSpan, JobBandPlan, RunOptions,
    SchedulerConfig,
};
pub use stats::{Histogram, HistogramSnapshot, Stats, StatsSnapshot, HIST_BOUNDS, HIST_BUCKETS};
