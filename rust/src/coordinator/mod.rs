//! Layer-3 coordinator: routing, scheduling, and telemetry.
//!
//! The coordinator owns the request path of LAMC: it takes the partition
//! planner's block jobs, routes each to an execution backend (the PJRT
//! artifact route when a compiled shape fits, the native Rust route
//! otherwise), fans them out over a worker pool with pull-based load
//! balancing, and collects per-route telemetry.

pub mod router;
pub mod scheduler;
pub mod stats;

pub use router::{BlockExecutor, NativeExecutor, PjrtExecutor, Route, Router};
pub use scheduler::{run_rounds, SchedulerConfig};
pub use stats::{Stats, StatsSnapshot};
