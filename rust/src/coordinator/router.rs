//! Execution routing: PJRT artifact route vs native Rust route.
//!
//! Implements the execution side of paper §IV-C (parallel block
//! co-clustering): each partition block is dispatched either to the
//! AOT-compiled XLA artifact (`pjrt` feature) or to the pure-Rust atom.
//! Without the `pjrt` feature the [`Router`] degenerates to the
//! [`NativeExecutor`] with no behavioural difference besides speed.

use std::sync::Arc;

use anyhow::Result;

use crate::cocluster::{AtomCocluster, CoclusterResult};
use crate::matrix::DenseMatrix;
use crate::rng::Xoshiro256;
#[cfg(feature = "pjrt")]
use crate::runtime::RuntimePool;

/// A backend that co-clusters one gathered block.
pub trait BlockExecutor: Send + Sync {
    fn name(&self) -> &str;
    fn execute(&self, block: &DenseMatrix, k: usize, seed: u64) -> Result<CoclusterResult>;
}

/// Native route: pure-Rust atom algorithm (SCC or PNMTF).
pub struct NativeExecutor {
    atom: Arc<dyn AtomCocluster>,
}

impl NativeExecutor {
    pub fn new(atom: Arc<dyn AtomCocluster>) -> Self {
        Self { atom }
    }
}

impl BlockExecutor for NativeExecutor {
    fn name(&self) -> &str {
        "native"
    }

    fn execute(&self, block: &DenseMatrix, k: usize, seed: u64) -> Result<CoclusterResult> {
        let mut rng = Xoshiro256::seed_from(seed);
        let m = crate::matrix::Matrix::Dense(block.clone());
        Ok(self.atom.cocluster(&m, k, &mut rng))
    }
}

/// PJRT route: AOT-compiled JAX/Pallas artifact via the runtime pool.
#[cfg(feature = "pjrt")]
pub struct PjrtExecutor {
    pool: Arc<RuntimePool>,
    /// Artifact kind this executor serves ("scc_block" / "pnmtf_block").
    kind: String,
}

#[cfg(feature = "pjrt")]
impl PjrtExecutor {
    pub fn new(pool: Arc<RuntimePool>, kind: impl Into<String>) -> Self {
        Self { pool, kind: kind.into() }
    }

    /// Does a compiled artifact fit this block without excessive padding?
    /// `max_pad_factor` bounds padded-area / block-area: padding zeros
    /// still cost FLOPs on the dense artifact graph.
    pub fn fits(&self, rows: usize, cols: usize, k: usize, max_pad_factor: f64) -> bool {
        match self.pool.spec_for(&self.kind, rows, cols, k) {
            Some(spec) => {
                let padded = (spec.phi * spec.psi) as f64;
                let actual = (rows * cols).max(1) as f64;
                padded / actual <= max_pad_factor
            }
            None => false,
        }
    }
}

#[cfg(feature = "pjrt")]
impl BlockExecutor for PjrtExecutor {
    fn name(&self) -> &str {
        "pjrt"
    }

    fn execute(&self, block: &DenseMatrix, k: usize, seed: u64) -> Result<CoclusterResult> {
        let spec = self
            .pool
            .spec_for(&self.kind, block.rows(), block.cols(), k)
            .ok_or_else(|| anyhow::anyhow!("no artifact fits {}x{} k={k}", block.rows(), block.cols()))?;
        self.pool.execute(spec, block.clone(), k, seed as i32)
    }
}

/// Which backend a job was routed to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    Pjrt,
    Native,
}

/// Routing policy: PJRT when available + fitting, else native; PJRT
/// errors fall back to native (counted in [`super::Stats`]). Built
/// without the `pjrt` feature, every job takes the native route.
pub struct Router {
    pub native: NativeExecutor,
    #[cfg(feature = "pjrt")]
    pub pjrt: Option<PjrtExecutor>,
    /// Maximum tolerated padding blow-up on the PJRT route.
    pub max_pad_factor: f64,
}

impl Router {
    pub fn native_only(atom: Arc<dyn AtomCocluster>) -> Self {
        Self {
            native: NativeExecutor::new(atom),
            #[cfg(feature = "pjrt")]
            pjrt: None,
            max_pad_factor: 1.7,
        }
    }

    #[cfg(feature = "pjrt")]
    pub fn with_runtime(atom: Arc<dyn AtomCocluster>, pool: Arc<RuntimePool>, kind: &str) -> Self {
        Self {
            native: NativeExecutor::new(atom),
            pjrt: Some(PjrtExecutor::new(pool, kind)),
            max_pad_factor: 1.7,
        }
    }

    /// Decide the route for a block shape.
    #[cfg_attr(not(feature = "pjrt"), allow(unused_variables))]
    pub fn route(&self, rows: usize, cols: usize, k: usize) -> Route {
        #[cfg(feature = "pjrt")]
        if let Some(p) = &self.pjrt {
            if p.fits(rows, cols, k, self.max_pad_factor) {
                return Route::Pjrt;
            }
        }
        Route::Native
    }

    /// Execute with fallback; returns the result and the route that
    /// actually produced it.
    pub fn execute(&self, block: &DenseMatrix, k: usize, seed: u64, stats: &super::Stats) -> Result<CoclusterResult> {
        use std::sync::atomic::Ordering;
        match self.route(block.rows(), block.cols(), k) {
            #[cfg(feature = "pjrt")]
            Route::Pjrt => {
                let pjrt = self.pjrt.as_ref().unwrap();
                match pjrt.execute(block, k, seed) {
                    Ok(r) => {
                        stats.blocks_pjrt.fetch_add(1, Ordering::Relaxed);
                        Ok(r)
                    }
                    Err(e) => {
                        crate::log_warn!("pjrt route failed ({e}); falling back to native");
                        stats.pjrt_fallbacks.fetch_add(1, Ordering::Relaxed);
                        stats.blocks_native.fetch_add(1, Ordering::Relaxed);
                        self.native.execute(block, k, seed)
                    }
                }
            }
            #[cfg(not(feature = "pjrt"))]
            Route::Pjrt => unreachable!("pjrt route cannot be chosen without the `pjrt` feature"),
            Route::Native => {
                stats.blocks_native.fetch_add(1, Ordering::Relaxed);
                self.native.execute(block, k, seed)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cocluster::SpectralCocluster;
    use crate::data::synthetic::{planted_dense, PlantedConfig};

    #[test]
    fn native_executor_runs_atom() {
        let ds = planted_dense(&PlantedConfig { rows: 60, cols: 50, seed: 601, ..Default::default() });
        let exec = NativeExecutor::new(Arc::new(SpectralCocluster::default()));
        let out = exec.execute(&ds.matrix.to_dense(), 4, 7).unwrap();
        out.validate(60, 50).unwrap();
        assert_eq!(exec.name(), "native");
    }

    #[test]
    fn native_executor_deterministic_by_seed() {
        let ds = planted_dense(&PlantedConfig { rows: 40, cols: 40, seed: 602, ..Default::default() });
        let exec = NativeExecutor::new(Arc::new(SpectralCocluster::default()));
        let a = exec.execute(&ds.matrix.to_dense(), 3, 9).unwrap();
        let b = exec.execute(&ds.matrix.to_dense(), 3, 9).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn router_without_pjrt_routes_native() {
        let router = Router::native_only(Arc::new(SpectralCocluster::default()));
        assert_eq!(router.route(256, 256, 4), Route::Native);
        let stats = crate::coordinator::Stats::default();
        let ds = planted_dense(&PlantedConfig { rows: 30, cols: 30, seed: 603, ..Default::default() });
        router.execute(&ds.matrix.to_dense(), 2, 1, &stats).unwrap();
        assert_eq!(stats.snapshot().blocks_native, 1);
        assert_eq!(stats.snapshot().blocks_pjrt, 0);
    }
}
