//! Out-of-core co-clustering: the matrix lives on disk, not in RAM.
//!
//! This example ingests a matrix **row by row** into a LAMC2 chunked
//! store — the full matrix is never resident; only the current row band
//! is — then runs the partitioned pipeline against the store through a
//! reader whose decoded-band cache is deliberately configured smaller
//! than the matrix. Peak memory is therefore bounded by
//!
//! ```text
//!   band cache budget  +  prefetch budget  +  workers × (block bytes)  +  labels
//! ```
//!
//! independent of matrix size: scale `LAMC_ROWS` up 100× and the bound
//! does not move (only the run gets longer). That is the §IV-B promise —
//! submatrix extraction only ever needs row/column tiles. The prefetch
//! pool is the background prefetcher's separately budgeted cache: the
//! scheduler hands the reader each upcoming round's chunk plan, so
//! band decodes overlap co-clustering instead of blocking gathers
//! (see docs/STORE.md § Prefetch).
//!
//! ```text
//! cargo run --release --example out_of_core
//! LAMC_ROWS=120000 cargo run --release --example out_of_core
//! ```

use lamc::pipeline::{Lamc, LamcConfig};
use lamc::rng::Xoshiro256;
use lamc::store::{ChunkWriter, Layout, MatrixRef, StoreReader};

fn main() -> anyhow::Result<()> {
    let rows: usize = std::env::var("LAMC_ROWS").ok().and_then(|s| s.parse().ok()).unwrap_or(12_000);
    let cols = 400usize;
    let k = 4usize;
    // The knobs this example is about: a band cache far below matrix
    // size, plus a bounded pool for the background prefetcher.
    let cache_budget = 4 << 20; // 4 MB
    let prefetch_budget = 2 << 20; // 2 MB
    let matrix_bytes = rows * cols * 4;

    let dir = std::env::temp_dir().join("lamc_out_of_core_example");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("planted_{rows}x{cols}.lamc2"));

    // --- Ingest: rows are generated and appended one at a time. -------
    // (In production this loop is a parser over your data source; `lamc
    // ingest` does the same from stdin.)
    println!("ingesting {rows} x {cols} ({:.1} MB dense) row by row …", matrix_bytes as f64 / 1e6);
    let mut writer = ChunkWriter::create(&path, Layout::Dense, cols, 256)?;
    let mut rng = Xoshiro256::seed_from(42);
    let mut row = vec![0.0f32; cols];
    for i in 0..rows {
        let block = (i * k / rows) % k; // planted row cluster
        for (j, v) in row.iter_mut().enumerate() {
            let signal = if (j * k / cols) % k == block { 1.5 } else { 0.0 };
            *v = signal + 0.3 * rng.next_normal() as f32;
        }
        writer.append_dense_row(&row)?;
    }
    let summary = writer.finish()?;
    println!(
        "store ready: {} bands of {} rows, fingerprint {:016x}",
        summary.chunks, summary.chunk_rows, summary.fingerprint
    );

    // --- Serve: the pipeline streams tiles; RAM stays bounded. --------
    let reader = StoreReader::open_with_budgets(&path, cache_budget, prefetch_budget)?;
    assert!(
        matrix_bytes > cache_budget,
        "this example wants the matrix ({matrix_bytes} B) larger than the band cache ({cache_budget} B)"
    );
    let stored = MatrixRef::stored(reader);
    let lamc = Lamc::new(LamcConfig { k, seed: 7, ..Default::default() });
    let out = lamc.run(&stored)?;

    println!("co-clustered out-of-core: k = {}, {:.2} s", out.k, out.elapsed_s);
    if let MatrixRef::Stored(reader) = &stored {
        println!(
            "I/O: {} tiles gathered, {} band reads from disk ({:.1} MB), {} band-cache hits",
            reader.tiles_served(),
            reader.chunks_read(),
            reader.bytes_read() as f64 / 1e6,
            reader.cache_hits(),
        );
        println!(
            "prefetch: {} bands fetched ahead, {} consumed by gathers, {} bytes wasted",
            reader.prefetch_issued(),
            reader.prefetch_hits(),
            reader.prefetch_wasted_bytes(),
        );
        println!(
            "peak resident bound: {:.1} MB cache + {:.1} MB prefetch pool + workers x block tiles (matrix itself: {:.1} MB, never loaded)",
            cache_budget as f64 / 1e6,
            prefetch_budget as f64 / 1e6,
            matrix_bytes as f64 / 1e6,
        );
        // The high-water mark shows how much of the budget the run
        // actually used (the ByteLru enforces the ceiling itself; the
        // interesting number is how hard the bound was pressed).
        println!(
            "band cache peaked at {:.1} MB of its {:.1} MB budget ({} evictions)",
            reader.cache_peak_bytes() as f64 / 1e6,
            cache_budget as f64 / 1e6,
            reader.cache_evictions(),
        );
    }
    Ok(())
}
