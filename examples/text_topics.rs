//! Topic discovery on a CLASSIC4-style document–term matrix.
//!
//! The workload the paper's intro motivates: co-clustering documents
//! and terms simultaneously so each topic comes with its vocabulary.
//! Compares the LAMC-PNMTF and LAMC-SCC atoms on the same corpus.
//!
//! ```text
//! cargo run --release --example text_topics
//! ```

use lamc::data::datasets;
use lamc::metrics::score_coclustering;
use lamc::pipeline::{AtomKind, Lamc, LamcConfig};

fn main() -> anyhow::Result<()> {
    // A scaled CLASSIC4: 6000 documents x 1000 terms, ~1.5% non-zeros,
    // 4 planted topics.
    let ds = datasets::build("classic4", Some(6000), 7).unwrap();
    println!(
        "corpus: {} docs x {} terms, {:.2}% nnz, 4 topics\n",
        ds.matrix.rows(),
        ds.matrix.cols(),
        100.0 * ds.matrix.nnz() as f64 / (ds.matrix.rows() * ds.matrix.cols()) as f64
    );

    for atom in [AtomKind::Scc, AtomKind::Pnmtf] {
        let lamc = Lamc::new(LamcConfig { k: 4, atom, seed: 7, ..Default::default() });
        let out = lamc.run(&ds.matrix)?;
        let s = score_coclustering(&ds.row_labels, &out.row_labels, &ds.col_labels, &out.col_labels);
        println!("LAMC-{atom:?}:");
        println!("  plan      : {}x{} of {}x{} (T_p={})", out.plan.m, out.plan.n, out.plan.phi, out.plan.psi, out.plan.t_p);
        println!("  topics    : {} found", out.k);
        println!("  time      : {:.3} s ({})", out.elapsed_s, out.stats);
        println!("  doc  NMI  : {:.4}  ARI {:.4}", s.row_nmi, s.row_ari);
        println!("  term NMI  : {:.4}  ARI {:.4}", s.col_nmi, s.col_ari);

        // Topic cards: document + vocabulary sizes per co-cluster.
        for (i, c) in out.coclusters.iter().enumerate().take(6) {
            println!("    topic {i}: {} docs, {} terms (consensus weight {})", c.rows.len(), c.cols.len(), c.weight);
        }
        println!();
    }
    Ok(())
}
