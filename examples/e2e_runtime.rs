//! END-TO-END DRIVER: the full three-layer system on a real workload.
//!
//! Proves every layer composes:
//!   L3 rust coordinator — probabilistic planning, shuffled partitions,
//!     worker pool, PJRT/native routing, hierarchical merge;
//!   L2 JAX block graph  — AOT-compiled spectral co-clustering, loaded
//!     from `artifacts/*.hlo.txt` and executed via PJRT;
//!   L1 Pallas kernels   — normalize / matmul / k-means-assign inlined
//!     in that graph.
//!
//! Workload: Amazon-1000-shaped dense matrix (1000x1000, k=5). Reports
//! per-route block counts, throughput, latency, and quality vs planted
//! truth; run is recorded in EXPERIMENTS.md §E2E.
//!
//! ```text
//! make artifacts && cargo run --release --features pjrt --example e2e_runtime
//! ```

use lamc::data;
use lamc::metrics::score_coclustering;
use lamc::pipeline::{Lamc, LamcConfig};
use lamc::runtime::{RuntimePool, RuntimePoolConfig};

fn main() -> anyhow::Result<()> {
    println!("=== LAMC end-to-end driver ===\n");

    // Layer check 1: artifacts present?
    let pool = match RuntimePool::from_default_manifest(RuntimePoolConfig { servers: 2 }) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("PJRT runtime unavailable: {e}\nRun `make artifacts` first.");
            std::process::exit(2);
        }
    };
    println!("[L2/L1] runtime online: {} AOT artifacts", pool.manifest().artifacts.len());
    for a in &pool.manifest().artifacts {
        println!("        {:<14} {:<12} {}x{} (rank {}, kmax {})", a.name, a.kind, a.phi, a.psi, a.rank, a.kmax);
    }

    // Workload.
    let ds = data::amazon1000(42);
    println!("\n[data ] amazon1000: {}x{} dense, 5 planted co-clusters", ds.matrix.rows(), ds.matrix.cols());

    // Run WITH the PJRT route.
    let lamc = Lamc::new(LamcConfig { k: 5, seed: 42, runtime: Some(pool), ..Default::default() });
    let with_rt = lamc.run(&ds.matrix)?;
    let s_rt = score_coclustering(&ds.row_labels, &with_rt.row_labels, &ds.col_labels, &with_rt.col_labels);

    // Same pipeline, native route only (ablation).
    let native = Lamc::new(LamcConfig { k: 5, seed: 42, runtime: None, ..Default::default() });
    let no_rt = native.run(&ds.matrix)?;
    let s_nat = score_coclustering(&ds.row_labels, &no_rt.row_labels, &ds.col_labels, &no_rt.col_labels);

    println!("\n[L3   ] plan: {}x{} grid of {}x{} blocks, T_p={} ({} block jobs)",
        with_rt.plan.m, with_rt.plan.n, with_rt.plan.phi, with_rt.plan.psi,
        with_rt.plan.t_p, with_rt.plan.total_blocks());

    println!("\n                      {:>12} {:>12}", "PJRT route", "native route");
    println!("wall time (s)         {:>12.3} {:>12.3}", with_rt.elapsed_s, no_rt.elapsed_s);
    println!("blocks via pjrt       {:>12} {:>12}", with_rt.stats.blocks_pjrt, no_rt.stats.blocks_pjrt);
    println!("blocks via native     {:>12} {:>12}", with_rt.stats.blocks_native, no_rt.stats.blocks_native);
    println!("pjrt fallbacks        {:>12} {:>12}", with_rt.stats.pjrt_fallbacks, no_rt.stats.pjrt_fallbacks);
    println!("gather time (s)       {:>12.3} {:>12.3}", with_rt.stats.gather_s, no_rt.stats.gather_s);
    println!("exec time (s)         {:>12.3} {:>12.3}", with_rt.stats.exec_s, no_rt.stats.exec_s);
    println!("merge time (s)        {:>12.3} {:>12.3}", with_rt.stats.merge_s, no_rt.stats.merge_s);
    let blocks = with_rt.plan.total_blocks() as f64;
    println!("blocks / s            {:>12.1} {:>12.1}", blocks / with_rt.elapsed_s, blocks / no_rt.elapsed_s);
    println!("per-block latency(ms) {:>12.1} {:>12.1}",
        1e3 * with_rt.stats.exec_s / blocks, 1e3 * no_rt.stats.exec_s / blocks);
    println!("NMI                   {:>12.4} {:>12.4}", s_rt.nmi(), s_nat.nmi());
    println!("ARI                   {:>12.4} {:>12.4}", s_rt.ari(), s_nat.ari());

    anyhow::ensure!(with_rt.stats.blocks_pjrt > 0, "no block took the PJRT route");
    anyhow::ensure!(s_rt.nmi() > 0.5, "PJRT-route quality collapsed");
    println!("\nE2E OK: all three layers composed (python never ran on this path).");
    Ok(())
}
