//! Quickstart: co-cluster a dense matrix with LAMC in ~20 lines.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use lamc::data;
use lamc::metrics::score_coclustering;
use lamc::pipeline::{Lamc, LamcConfig};

fn main() -> anyhow::Result<()> {
    // 1. A workload: the Amazon-1000-shaped dense dataset (1000x1000,
    //    5 planted co-clusters — see DESIGN.md §4 for the substitution).
    let ds = data::amazon1000(42);

    // 2. Configure and run LAMC. Defaults: spectral atom, probabilistic
    //    partition planning at P_thresh = 0.95, hierarchical merging.
    let lamc = Lamc::new(LamcConfig { k: 5, ..Default::default() });
    let result = lamc.run(&ds.matrix)?;

    // 3. Inspect.
    println!("partition plan : {}x{} blocks of {}x{}, T_p = {}",
        result.plan.m, result.plan.n, result.plan.phi, result.plan.psi, result.plan.t_p);
    println!("co-clusters    : {}", result.k);
    println!("wall time      : {:.3} s", result.elapsed_s);
    println!("coordinator    : {}", result.stats);

    let s = score_coclustering(&ds.row_labels, &result.row_labels, &ds.col_labels, &result.col_labels);
    println!("quality        : NMI {:.4}, ARI {:.4}", s.nmi(), s.ari());

    // 4. The co-clusters themselves (row/col index sets).
    for (i, c) in result.coclusters.iter().take(5).enumerate() {
        println!("  cluster {i}: {} rows x {} cols (weight {})", c.rows.len(), c.cols.len(), c.weight);
    }
    Ok(())
}
