//! Scalability demonstration on the RCV1-Large-style workload.
//!
//! This is the regime where the classical baselines hit the wall
//! (Table II's asterisks): full-matrix exact-SVD SCC is infeasible; the
//! partitioned pipeline streams through. The example sweeps matrix
//! size, showing near-linear scaling of LAMC against the cubic-ish cost
//! model of the classical baseline.
//!
//! ```text
//! cargo run --release --example large_scale_sparse          # default sweep
//! LAMC_ROWS=60000 cargo run --release --example large_scale_sparse
//! ```

use lamc::data::datasets;
use lamc::harness::{estimated_flops, Method};
use lamc::metrics::score_coclustering;
use lamc::pipeline::{Lamc, LamcConfig};

fn main() -> anyhow::Result<()> {
    let sizes: Vec<usize> = match std::env::var("LAMC_ROWS") {
        Ok(s) => vec![s.parse()?],
        Err(_) => vec![5_000, 10_000, 20_000],
    };

    println!("{:<14} {:>10} {:>8} {:>9} {:>8} {:>8}  {}", "rows x cols", "nnz", "T_p", "time (s)", "NMI", "ARI", "SCC-exact est.");
    for rows in sizes {
        let ds = datasets::build("rcv1_large", Some(rows), 11).unwrap();
        let lamc = Lamc::new(LamcConfig { k: 6, seed: 11, ..Default::default() });
        let out = lamc.run(&ds.matrix)?;
        let s = score_coclustering(&ds.row_labels, &out.row_labels, &ds.col_labels, &out.col_labels);
        // What the classical baseline *would* cost (why it's starred).
        let scc_flops = estimated_flops(Method::Scc, ds.matrix.rows(), ds.matrix.cols(), 6);
        println!(
            "{:<14} {:>10} {:>8} {:>9.3} {:>8.4} {:>8.4}  {:.2e} FLOPs ({})",
            format!("{}x{}", ds.matrix.rows(), ds.matrix.cols()),
            ds.matrix.nnz(),
            out.plan.t_p,
            out.elapsed_s,
            s.nmi(),
            s.ari(),
            scc_flops,
            if scc_flops > lamc::harness::budget_flops() { "infeasible: '*'" } else { "feasible" },
        );
    }
    println!("\nMemory note: CSR storage keeps the 60000x2000 full dataset at ~");
    println!("a few hundred MB; the dense equivalent would not fit the budget —");
    println!("this is the 'Dependency on Sparse Matrices' challenge from §I.");
    Ok(())
}
