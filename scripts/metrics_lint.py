#!/usr/bin/env python3
"""Well-formedness linter for the METRICS Prometheus-text exposition.

CI scrapes a live ``lamc serve`` worker and a ``lamc route`` router and
pipes the exposition through this linter. It enforces the contract
documented in ``docs/OBSERVABILITY.md`` § Metrics exposition:

* Every sampled family carries a ``# HELP`` and a ``# TYPE`` line, and
  the declared type is one of ``counter``/``gauge``/``histogram``.
* No family is declared twice, and no declaration is left dangling
  (HELP without TYPE or vice versa).
* Histogram series are complete and ordered: within one label set the
  ``le`` bounds are strictly increasing and terminated by ``+Inf``,
  bucket counts are non-decreasing (cumulative), and the ``_count``
  sample equals the ``+Inf`` bucket. ``_sum`` and ``_count`` exist for
  every bucketed label set.
* Sample values parse as finite numbers.

The linter is schema-driven, not name-driven: it knows nothing about
which families lamc exposes, so new metrics are covered the moment they
are sampled.

Usage:
  metrics_lint.py FILE [FILE...]   # lint exposition file(s); '-' = stdin
  metrics_lint.py --self-test

``--self-test`` lints a known-good synthetic exposition and then four
deliberately malformed variants (missing HELP, unordered ``le``,
missing ``+Inf``, ``_count`` disagreeing with the terminal bucket),
asserting the linter rejects each — CI runs this first so a silently
broken linter can never wave a malformed exposition through.
"""

import argparse
import math
import re
import sys

TYPES = {"counter", "gauge", "histogram"}

SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r"\s+(?P<value>\S+)\s*$"
)
LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_labels(raw):
    """'a="x",le="0.5"' -> ({'a': 'x'}, problems). Order-insensitive."""
    problems = []
    labels = {}
    if raw is None or raw.strip() == "":
        return labels, problems
    matched = LABEL_RE.findall(raw)
    # Reconstruct to catch garbage the regex skipped over (bare words,
    # missing quotes): the matches must tile the whole label body.
    rebuilt = ",".join(f'{k}="{v}"' for k, v in matched)
    if rebuilt != raw.strip().rstrip(","):
        problems.append(f"unparseable label body {{{raw}}}")
    for k, v in matched:
        if k in labels:
            problems.append(f"duplicate label {k!r} in {{{raw}}}")
        labels[k] = v
    return labels, problems


def base_family(name, typed):
    """Map a sample name to its declared family: histogram samples
    (``_bucket``/``_sum``/``_count``) belong to the stripped name when
    that name is declared as a histogram."""
    for suffix in ("_bucket", "_sum", "_count"):
        if name.endswith(suffix):
            stem = name[: -len(suffix)]
            if typed.get(stem) == "histogram":
                return stem
    return name


def lint_text(text, source="<exposition>"):
    """Return a list of problem strings (empty = well-formed)."""
    problems = []
    helped, typed = {}, {}
    samples = []  # (lineno, name, labels_dict, value)

    for lineno, line in enumerate(text.splitlines(), 1):
        where = f"{source}:{lineno}"
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            parts = line.split(None, 3)
            if len(parts) < 4 or not parts[3].strip():
                problems.append(f"{where}: HELP without help text: {line!r}")
                continue
            name = parts[2]
            if name in helped:
                problems.append(f"{where}: duplicate HELP for {name}")
            helped[name] = parts[3]
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or parts[3] not in TYPES:
                problems.append(f"{where}: malformed TYPE line: {line!r}")
                continue
            name = parts[2]
            if name in typed:
                problems.append(f"{where}: duplicate TYPE for {name}")
            typed[name] = parts[3]
            continue
        if line.startswith("#"):
            continue  # free-form comment: legal, ignored
        m = SAMPLE_RE.match(line)
        if not m:
            problems.append(f"{where}: unparseable sample line: {line!r}")
            continue
        labels, label_problems = parse_labels(m.group("labels"))
        problems.extend(f"{where}: {p}" for p in label_problems)
        try:
            value = float(m.group("value"))
        except ValueError:
            problems.append(f"{where}: non-numeric value: {line!r}")
            continue
        if math.isnan(value) or math.isinf(value):
            problems.append(f"{where}: non-finite value: {line!r}")
            continue
        samples.append((lineno, m.group("name"), labels, value))

    # Declarations must pair up, families must be declared before use.
    for name in sorted(set(helped) | set(typed)):
        if name not in helped:
            problems.append(f"{source}: {name} has TYPE but no HELP")
        if name not in typed:
            problems.append(f"{source}: {name} has HELP but no TYPE")

    sampled_families = set()
    for lineno, name, labels, value in samples:
        fam = base_family(name, typed)
        sampled_families.add(fam)
        if fam not in typed:
            problems.append(
                f"{source}:{lineno}: sample {name} belongs to undeclared "
                f"family {fam} (no # TYPE)"
            )
        if fam not in helped:
            problems.append(
                f"{source}:{lineno}: sample {name} belongs to family "
                f"{fam} with no # HELP"
            )
    for name in sorted(set(typed)):
        if name not in sampled_families:
            problems.append(f"{source}: {name} declared but never sampled")

    problems.extend(lint_histograms(samples, typed, source))
    return problems


def hist_key(labels):
    """Label identity of one histogram series, ``le`` excluded."""
    return tuple(sorted((k, v) for k, v in labels.items() if k != "le"))


def lint_histograms(samples, typed, source):
    problems = []
    hist_fams = {n for n, t in typed.items() if t == "histogram"}
    # family -> series key -> {"buckets": [(le, value)], "sum": v, "count": v}
    series = {}
    for lineno, name, labels, value in samples:
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix) and name[: -len(suffix)] in hist_fams:
                fam = name[: -len(suffix)]
                rec = series.setdefault(fam, {}).setdefault(
                    hist_key(labels), {"buckets": [], "sum": None, "count": None}
                )
                if suffix == "_bucket":
                    if "le" not in labels:
                        problems.append(
                            f"{source}:{lineno}: {name} bucket without an "
                            f"le label"
                        )
                        break
                    le = labels["le"]
                    bound = math.inf if le == "+Inf" else None
                    if bound is None:
                        try:
                            bound = float(le)
                        except ValueError:
                            problems.append(
                                f"{source}:{lineno}: unparseable le={le!r} "
                                f"on {name}"
                            )
                            break
                    rec["buckets"].append((bound, value, lineno))
                else:
                    rec[suffix[1:]] = value
                break
        else:
            if name in hist_fams:
                problems.append(
                    f"{source}:{lineno}: {name} is declared a histogram but "
                    f"sampled bare (expected _bucket/_sum/_count series)"
                )

    for fam in sorted(series):
        for key, rec in sorted(series[fam].items()):
            tag = f"{fam}{{{', '.join(f'{k}={v!r}' for k, v in key)}}}"
            buckets = rec["buckets"]  # exposition order
            if not buckets:
                problems.append(f"{source}: {tag} has _sum/_count but no buckets")
                continue
            bounds = [b for b, _, _ in buckets]
            if any(b2 <= b1 for b1, b2 in zip(bounds, bounds[1:])):
                problems.append(f"{source}: {tag} le bounds not strictly increasing")
            counts = [v for _, v, _ in buckets]
            if any(c2 < c1 for c1, c2 in zip(counts, counts[1:])):
                problems.append(f"{source}: {tag} bucket counts not cumulative")
            if bounds[-1] != math.inf:
                problems.append(f"{source}: {tag} missing terminal le=\"+Inf\" bucket")
            elif rec["count"] is not None and rec["count"] != counts[-1]:
                problems.append(
                    f"{source}: {tag} _count={rec['count']:g} disagrees with "
                    f"+Inf bucket {counts[-1]:g}"
                )
            if rec["sum"] is None:
                problems.append(f"{source}: {tag} missing _sum")
            if rec["count"] is None:
                problems.append(f"{source}: {tag} missing _count")
    return problems


GOOD = """\
# HELP lamc_jobs Jobs on this node, by lifecycle state.
# TYPE lamc_jobs gauge
lamc_jobs{state="queued"} 0
lamc_jobs{state="done"} 7
# HELP lamc_store_chunks_read_total Chunks decoded from disk.
# TYPE lamc_store_chunks_read_total counter
lamc_store_chunks_read_total 96
# HELP lamc_round_seconds Phase latency distribution, by phase.
# TYPE lamc_round_seconds histogram
lamc_round_seconds_bucket{phase="gather",le="0.001"} 2
lamc_round_seconds_bucket{phase="gather",le="0.005"} 5
lamc_round_seconds_bucket{phase="gather",le="+Inf"} 9
lamc_round_seconds_sum{phase="gather"} 0.412331000
lamc_round_seconds_count{phase="gather"} 9
# HELP lamc_queue_wait_seconds Seconds jobs waited in queue.
# TYPE lamc_queue_wait_seconds histogram
lamc_queue_wait_seconds_bucket{le="0.001"} 1
lamc_queue_wait_seconds_bucket{le="+Inf"} 1
lamc_queue_wait_seconds_sum 0.000412000
lamc_queue_wait_seconds_count 1
"""


def self_test():
    problems = lint_text(GOOD, "good")
    assert not problems, f"well-formed exposition flagged: {problems}"
    print("self-test: well-formed exposition passes")

    broken = {
        "missing HELP": GOOD.replace(
            "# HELP lamc_store_chunks_read_total Chunks decoded from disk.\n", ""
        ),
        "unordered le": GOOD.replace('le="0.005"', 'le="0.0005"'),
        "missing +Inf": GOOD.replace(
            'lamc_round_seconds_bucket{phase="gather",le="+Inf"} 9\n', ""
        ),
        "_count vs +Inf": GOOD.replace(
            'lamc_round_seconds_count{phase="gather"} 9',
            'lamc_round_seconds_count{phase="gather"} 12',
        ),
    }
    for label, text in broken.items():
        problems = lint_text(text, label)
        assert problems, f"linter passed a malformed exposition ({label})"
        print(f"self-test: {label} rejected — {problems[0]}")
    print("self-test OK")
    return 0


def main(argv):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="*", help="exposition file(s); '-' = stdin")
    ap.add_argument("--self-test", action="store_true",
                    help="verify the linter rejects malformed expositions")
    args = ap.parse_args(argv)

    if args.self_test:
        return self_test()
    if not args.files:
        ap.error("at least one FILE is required (or use --self-test)")

    rc = 0
    for path in args.files:
        text = sys.stdin.read() if path == "-" else open(path).read()
        source = "<stdin>" if path == "-" else path
        problems = lint_text(text, source)
        if problems:
            rc = 1
            print(f"{source}: {len(problems)} problem(s):")
            for p in problems:
                print(f"  {p}")
        else:
            lines = sum(1 for l in text.splitlines() if l.strip())
            print(f"{source}: well-formed ({lines} non-blank lines)")
    return rc


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
