#!/usr/bin/env python3
"""Perf-trajectory regression gate for the CI bench artifacts.

Compares the current run's ``BENCH_N.json`` against the previous run's
artifact (downloaded from the last successful workflow run on the same
branch) and fails when any wall-clock metric regressed beyond the
tolerance. Policy, metric naming, and the rationale for the default
tolerance live in ``docs/BENCHMARKS.md``.

Rules (deliberately few — shared CI runners are noisy):

* Only keys that name a duration are compared (``*_s``, ``*_seconds``,
  ``median_s``/``min_s`` leaves). Everything else (counts, reductions,
  speedups, strings) is trajectory data, not a gate.
* Lower is better. ``current > previous * (1 + tolerance/100)`` on any
  compared key fails the gate; improvements never fail it.
* Baselines under ``--min-seconds`` (default 5 ms) are skipped — at
  that scale runner jitter swamps the signal.
* A missing/unreadable previous artifact passes with a note: the first
  run on a branch seeds the trajectory instead of failing it.

Usage:
  bench_compare.py --current BENCH_9.json [--previous PREV.json]
                   [--tolerance PCT] [--min-seconds S]
  bench_compare.py --self-test

``--self-test`` builds a synthetic previous/current pair with one
injected regression and asserts the gate fails on it (and passes once
the regression is removed) — CI runs this first so a silently broken
comparator can never wave a real regression through.
"""

import argparse
import json
import sys


def flatten(obj, prefix=""):
    """Flatten nested dicts to {dotted.path: leaf} (lists are opaque)."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            path = f"{prefix}.{k}" if prefix else str(k)
            out.update(flatten(v, path))
    else:
        out[prefix] = obj
    return out


def is_duration_key(path):
    leaf = path.rsplit(".", 1)[-1]
    return leaf.endswith("_s") or leaf.endswith("_seconds")


def compare(previous, current, tolerance_pct, min_seconds):
    """Return (failures, checked) comparing duration keys of two dicts.

    ``failures`` is a list of human-readable regression lines; ``checked``
    counts the keys actually gated.
    """
    prev = flatten(previous)
    curr = flatten(current)
    failures = []
    checked = 0
    for path in sorted(curr):
        if not is_duration_key(path) or path not in prev:
            continue
        p, c = prev[path], curr[path]
        if not isinstance(p, (int, float)) or not isinstance(c, (int, float)):
            continue
        if p < min_seconds:
            print(f"  skip  {path}: baseline {p:.6f}s < {min_seconds}s floor")
            continue
        checked += 1
        limit = p * (1.0 + tolerance_pct / 100.0)
        verdict = "FAIL" if c > limit else "ok"
        print(f"  {verdict:<5} {path}: {p:.4f}s -> {c:.4f}s (limit {limit:.4f}s)")
        if c > limit:
            failures.append(
                f"{path}: {c:.4f}s vs previous {p:.4f}s "
                f"(+{100.0 * (c / p - 1.0):.1f}%, tolerance {tolerance_pct:.0f}%)"
            )
    return failures, checked


def self_test(tolerance_pct, min_seconds):
    previous = {
        "bench_id": 7,
        "headline": {"t_scc_dense_s": 2.0, "t_lamc_scc_dense_s": 0.40},
        "prefetch": {"prefetch_on": {"median_s": 0.100, "runs": 5}},
        "tiny": {"noise_s": 0.0001},
    }
    # Injected regression: t_lamc_scc_dense_s 0.40 -> 1.20 (+200%).
    current = json.loads(json.dumps(previous))
    current["headline"]["t_lamc_scc_dense_s"] = 1.20
    current["tiny"]["noise_s"] = 0.0009  # 9x, but under the floor: ignored

    print("self-test: injected regression must fail the gate")
    failures, checked = compare(previous, current, tolerance_pct, min_seconds)
    assert checked >= 3, f"expected >=3 gated keys, got {checked}"
    assert len(failures) == 1, f"expected exactly 1 failure, got {failures}"
    assert "t_lamc_scc_dense_s" in failures[0], failures[0]

    print("self-test: identical runs must pass the gate")
    failures, _ = compare(previous, previous, tolerance_pct, min_seconds)
    assert not failures, f"identical runs flagged: {failures}"

    print("self-test: missing previous artifact must pass (trajectory seed)")
    rc = run_gate(None, current, tolerance_pct, min_seconds)
    assert rc == 0, "missing previous artifact should not fail the gate"

    print("self-test OK")
    return 0


def run_gate(previous, current, tolerance_pct, min_seconds):
    if previous is None:
        print("no previous bench artifact — seeding the trajectory, gate passes")
        return 0
    failures, checked = compare(previous, current, tolerance_pct, min_seconds)
    if failures:
        print(f"\nperf regression gate FAILED ({len(failures)} metric(s)):")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"\nperf regression gate passed ({checked} metric(s) within tolerance)")
    return 0


def load_optional(path):
    """Previous artifact: tolerate absence and damage (first run, expired
    artifact, truncated download) — those seed the trajectory."""
    if path is None:
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"previous artifact unusable ({e}) — treating as missing")
        return None


def main(argv):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", help="this run's BENCH_N.json")
    ap.add_argument("--previous", help="previous run's artifact (may be absent)")
    ap.add_argument("--tolerance", type=float, default=40.0,
                    help="allowed slowdown in percent (default 40; docs/BENCHMARKS.md)")
    ap.add_argument("--min-seconds", type=float, default=0.005,
                    help="skip metrics whose baseline is below this (default 5 ms)")
    ap.add_argument("--self-test", action="store_true",
                    help="verify the gate fails on an injected regression")
    args = ap.parse_args(argv)

    if args.self_test:
        return self_test(args.tolerance, args.min_seconds)
    if not args.current:
        ap.error("--current is required (or use --self-test)")
    with open(args.current) as f:
        current = json.load(f)
    return run_gate(load_optional(args.previous), current, args.tolerance,
                    args.min_seconds)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
